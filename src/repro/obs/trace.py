"""Host-side spans, the metric registry, and the JSONL event sink.

The observability substrate for the whole GP inference stack
(DESIGN.md sec. 13, docs/observability.md).  Three pieces:

  * ``span(name)``  — nestable context managers with monotonic timing.
    Nesting builds a dotted path (``span("hmc.phase2")`` inside
    ``span("hmc.gpg_hmc")`` records ``hmc.gpg_hmc.hmc.phase2``); every
    completed span observes a ``span.<path>.seconds`` histogram and, when
    a sink is configured, appends one JSONL event.  With
    ``REPRO_OBS_PROFILER=on`` each span additionally opens a
    ``jax.profiler.TraceAnnotation`` so the same names show up inside
    Perfetto/TensorBoard device traces.
  * ``Registry``    — a process-global store of counters (monotonic),
    gauges (last value) and histograms (count/total/min/max).  Cheap
    enough to be always-on internally; the *wiring call sites* across
    core/train/hyper are gated on :func:`enabled` so disabled mode costs
    one predicate per call.
  * JSONL sink      — ``configure(jsonl=path)`` (or the
    ``REPRO_OBS_JSONL`` env var) appends events as JSON lines;
    ``flush()`` writes a full registry snapshot event, and an atexit
    hook writes a final one, so ``tools/check_telemetry.py`` can gate a
    run from the log alone.

The master switch is the ``REPRO_OBS`` env var (default OFF) or
``set_enabled``/``use_obs``.  Everything here is host-side python — when
disabled, nothing in this module touches a jaxpr, and the in-jit taps
(``repro.obs.injit``) are trace-time no-ops, so compiled programs are
bit-identical with observability off (asserted in tests/test_obs.py).
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
import threading
import time
from typing import Any, Iterator, Optional

_ON_VALUES = ("1", "on", "true", "yes")

_FORCED: Optional[bool] = None
_LOCK = threading.RLock()
_TLS = threading.local()


# ---------------------------------------------------------------------------
# The master switch
# ---------------------------------------------------------------------------

def enabled() -> bool:
    """Whether observability is on: ``set_enabled`` override > REPRO_OBS."""
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_OBS", "").strip().lower() in _ON_VALUES


def set_enabled(on: Optional[bool]) -> None:
    """Force observability on/off; ``None`` restores env-var resolution."""
    global _FORCED
    _FORCED = None if on is None else bool(on)


@contextlib.contextmanager
def use_obs(on: bool = True) -> Iterator[None]:
    """Scoped ``set_enabled`` — the test suite's harness."""
    prev = _FORCED
    set_enabled(on)
    try:
        yield
    finally:
        set_enabled(prev)


def _profiler_on() -> bool:
    return os.environ.get("REPRO_OBS_PROFILER", "").strip().lower() \
        in _ON_VALUES


# ---------------------------------------------------------------------------
# Registry: counters / gauges / histograms
# ---------------------------------------------------------------------------

class Hist:
    """count/total/min/max summary of an observed stream of scalars."""

    __slots__ = ("count", "total", "min", "max", "last")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.last = v

    def to_dict(self) -> dict:
        return {"count": self.count, "total": self.total,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0, "last": self.last}


class Registry:
    """Process-global counters/gauges/histograms (thread-safe)."""

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, Hist] = {}

    def inc(self, name: str, n: float = 1.0) -> None:
        with _LOCK:
            self.counters[name] = self.counters.get(name, 0.0) + float(n)

    def set_gauge(self, name: str, v: float) -> None:
        with _LOCK:
            self.gauges[name] = float(v)

    def observe(self, name: str, v: float) -> None:
        with _LOCK:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Hist()
            h.observe(v)

    def snapshot(self) -> dict:
        with _LOCK:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "hists": {k: h.to_dict() for k, h in self.hists.items()},
            }

    def delta(self, before: dict) -> dict:
        """Registry change since a previous :meth:`snapshot` — counter and
        histogram count/total deltas (zero-delta counters dropped), gauges
        at their current values.  The per-bench ``telemetry`` sections of
        ``benchmarks/run.py`` are built from this."""
        cur = self.snapshot()
        b_c = before.get("counters", {})
        b_h = before.get("hists", {})
        counters = {k: v - b_c.get(k, 0.0) for k, v in cur["counters"].items()
                    if v - b_c.get(k, 0.0) != 0.0}
        hists = {}
        for k, h in cur["hists"].items():
            dc = h["count"] - b_h.get(k, {}).get("count", 0)
            if dc:
                hists[k] = {"count": dc,
                            "total": h["total"] - b_h.get(k, {}).get(
                                "total", 0.0),
                            "last": h["last"]}
        return {"counters": counters, "gauges": cur["gauges"],
                "hists": hists}

    def reset(self) -> None:
        with _LOCK:
            self.counters.clear()
            self.gauges.clear()
            self.hists.clear()


REGISTRY = Registry()


def counter_value(name: str) -> float:
    return REGISTRY.counters.get(name, 0.0)


def gauge_value(name: str, default: float = 0.0) -> float:
    return REGISTRY.gauges.get(name, default)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------

_SINK = None            # open file object, or None
_SINK_PATH: Optional[str] = None
_SINK_EXPLICIT = False  # configure() beats the env var
_ATEXIT_ARMED = False


def configure(jsonl: Optional[str] = None) -> None:
    """Point the event sink at ``jsonl`` (append mode); ``None`` closes it
    and restores ``REPRO_OBS_JSONL`` env resolution."""
    global _SINK, _SINK_PATH, _SINK_EXPLICIT
    with _LOCK:
        if _SINK is not None:
            _SINK.close()
            _SINK = None
        _SINK_PATH = jsonl
        _SINK_EXPLICIT = jsonl is not None


def _get_sink():
    global _SINK, _SINK_PATH, _ATEXIT_ARMED
    with _LOCK:
        if _SINK is not None:
            return _SINK
        path = _SINK_PATH if _SINK_EXPLICIT else \
            os.environ.get("REPRO_OBS_JSONL") or None
        if not path:
            return None
        _SINK = open(path, "a", encoding="utf-8")
        if not _ATEXIT_ARMED:
            _ATEXIT_ARMED = True
            atexit.register(_final_flush)
        return _SINK


def emit(event: dict) -> None:
    """Append one event to the JSONL sink (no-op when disabled/unsinked)."""
    if not enabled():
        return
    sink = _get_sink()
    if sink is None:
        return
    event.setdefault("t", time.time())
    with _LOCK:
        sink.write(json.dumps(event, default=str) + "\n")
        sink.flush()


def flush() -> None:
    """Write a full registry snapshot event to the sink."""
    emit({"type": "snapshot", **REGISTRY.snapshot()})


def _final_flush() -> None:
    try:
        if enabled() and _get_sink() is not None:
            flush()
    except Exception:       # noqa: BLE001 — never fail interpreter exit
        pass


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def span(name: str, **attrs: Any) -> Iterator[Optional[str]]:
    """A timed, nestable span.  Disabled mode: a bare nullcontext-grade
    no-op (one ``enabled()`` predicate).  Enabled: monotonic duration into
    the ``span.<path>.seconds`` histogram + one JSONL event, and a
    ``jax.profiler.TraceAnnotation`` when ``REPRO_OBS_PROFILER=on`` so
    the span lands in Perfetto/TensorBoard device traces."""
    if not enabled():
        yield None
        return
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    path = ".".join(stack + [name])
    stack.append(name)
    wall = time.time()
    t0 = time.monotonic()
    prof = contextlib.nullcontext()
    if _profiler_on():
        import jax

        prof = jax.profiler.TraceAnnotation(path)
    try:
        with prof:
            yield path
    finally:
        stack.pop()
        dur = time.monotonic() - t0
        REGISTRY.observe(f"span.{path}.seconds", dur)
        ev = {"type": "span", "name": name, "path": path, "t": wall,
              "dur_s": dur}
        if attrs:
            ev["attrs"] = attrs
        emit(ev)

"""In-jit metric taps: traced scalars -> the host registry.

Traceable code cannot touch :mod:`repro.obs.trace` directly (python side
effects are trace-time only), and widening result pytrees with metric
fields would change every caller's jaxpr — the off-mode zero-cost
guarantee forbids that.  Instead, hot loops *tap*: :func:`tap` stages a
``jax.debug.callback`` that folds the traced scalar into the registry
when the compiled program runs.  ``debug.callback`` is the right
primitive here (not ``io_callback``): its Debug effect is legal inside
``lax.cond``/``lax.scan`` bodies, which is exactly where the bordered-
Cholesky degenerate branch and the CG loop live.

The gate is TRACE-time: ``tap`` returns immediately when observability
is disabled, so nothing enters the jaxpr — disabled-mode programs are
bit-identical to pre-obs ones (asserted via
``count_primitive(jaxpr, "debug_callback") == 0`` in tests/test_obs.py).
Consequence: enable obs BEFORE first compilation; a function compiled
with taps keeps them (cache), and one compiled without has none.

For callers that prefer to carry metrics out of jit explicitly (e.g.
a scan that accumulates per-step scalars), ``metrics_of_state`` /
``fold`` convert a ``GPGData``-style counter block into registry
updates on the host — the "Metrics pytree" escape hatch.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping

from repro.obs import trace as _trace

enabled = _trace.enabled


def _fold_one(name: str, kind: str, value) -> None:
    # Under vmap (the fleet's batched state machine) the callback receives
    # the whole (B,) lane vector at once: counters fold the lane SUM (the
    # fleet-wide total), gauges the lane mean, and hists observe per lane
    # (bounded: a fleet batch is a few dozen lanes, not a data axis).
    import numpy as np

    v = np.asarray(value)
    if v.ndim:
        if kind == "counter":
            _trace.REGISTRY.inc(name, float(v.sum()))
        elif kind == "hist":
            for x in v.ravel():
                _trace.REGISTRY.observe(name, float(x))
        else:
            _trace.REGISTRY.set_gauge(name, float(v.mean()))
        return
    v = float(v)
    if kind == "counter":
        _trace.REGISTRY.inc(name, v)
    elif kind == "hist":
        _trace.REGISTRY.observe(name, v)
    else:
        _trace.REGISTRY.set_gauge(name, v)


def tap(name: str, value, kind: str = "gauge") -> None:
    """Stage a host fold of traced scalar ``value`` under ``name``.

    ``kind``: ``"gauge"`` (last value), ``"counter"`` (accumulate), or
    ``"hist"`` (observe into a histogram).  Trace-time no-op when
    observability is disabled — zero jaxpr footprint.  Works in eager
    mode too (the callback runs immediately).
    """
    if not enabled():
        return
    import jax

    jax.debug.callback(partial(_fold_one, name, kind), value)


def tap_metrics(metrics: Mapping[str, object], kind: str = "gauge") -> None:
    """Tap every entry of a {name: traced scalar} mapping."""
    if not enabled():
        return
    for name, value in metrics.items():
        tap(name, value, kind=kind)


# ---------------------------------------------------------------------------
# Explicit Metrics-pytree escape hatch (host side)
# ---------------------------------------------------------------------------

#: A Metrics value is just a flat {name: scalar} dict — any pytree of
#: scalars a traced function chooses to return alongside its result.
Metrics = dict


def metrics_of_state(data) -> Metrics:
    """Standard metric block extracted from a ``GPGData`` pytree."""
    return {
        "state.count": data.count,
        "state.cg_iters": data.cg_iters,
        "state.cg_resnorm": data.resnorm,
        "state.n_refactor": data.n_refactor,
        "state.n_solve": data.n_solve,
    }


def fold(metrics: Mapping[str, object], kind: str = "gauge") -> None:
    """Fold a concrete (already device-fetched) Metrics dict into the
    registry on the host.  Call this OUTSIDE jit, on jit outputs."""
    if not enabled():
        return
    for name, value in metrics.items():
        _fold_one(name, kind, value)

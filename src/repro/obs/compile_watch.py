"""Recompile sentinel: count XLA compiles per shape signature.

The serve step's contract (DESIGN.md sec. 9/13) is that extend, evict,
refit, and precision toggles within one geometry never retrigger XLA
compilation — only genuinely new shape signatures do.  Pre-obs that was
folklore; :func:`wrap` makes it an asserted runtime invariant.

Mechanism: the wrapped function gets an inert zero-size marker argument
closed over per watcher; a host callback placed FIRST in the traced body
runs once per trace (jit caches by signature, so a cache hit never
re-traces).  Each trace increments ``compile.<name>.compiles`` and a
per-signature table; the *n-th* trace of a signature already seen
(n > 1) is a violation: ``compile.<name>.recompiles`` increments and a
``{"type": "compile", "nth": n}`` event with n > 1 lands in the JSONL —
which ``tools/check_telemetry.py`` treats as a hard failure.

Signatures are (treedef, per-leaf (shape, dtype)) — matching jit's own
cache granularity for weak-typed python scalars is not attempted;
instead python numbers hash by type only, mirroring jit's
value-independence for float leaves (a refit that only changes noise
values keeps the signature AND jit's cache entry: no trace, no event).

When observability is disabled, :func:`wrap` returns a plain
``jax.jit(fn)`` — bit-identical behavior to pre-obs code.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.obs import trace as _trace

_WATCHES: list["CompileWatch"] = []


def _leaf_sig(leaf: Any) -> tuple:
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is not None and dtype is not None:
        return (tuple(shape), str(dtype))
    return (type(leaf).__name__, ())


def signature(args: tuple, kwargs: dict) -> tuple:
    """Hashable (treedef, leaf avals) key for an argument bundle."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    return (treedef, tuple(_leaf_sig(leaf) for leaf in leaves))


class CompileWatch:
    """A jitted callable that records every trace per shape signature."""

    def __init__(self, fn: Callable, name: str, **jit_kwargs: Any):
        import jax

        self.name = name
        self.calls = 0
        self.compiles: dict[tuple, int] = {}
        self._current: tuple | None = None
        _trace.REGISTRY.inc(f"compile.{name}.compiles", 0)
        _trace.REGISTRY.inc(f"compile.{name}.recompiles", 0)

        def shimmed(*args, **kwargs):
            # Runs at TRACE time only — jit cache hits skip it entirely.
            self._record_trace()
            return fn(*args, **kwargs)

        self._jitted = jax.jit(shimmed, **jit_kwargs)
        _WATCHES.append(self)

    def _record_trace(self) -> None:
        sig = self._current
        nth = self.compiles.get(sig, 0) + 1
        self.compiles[sig] = nth
        _trace.REGISTRY.inc(f"compile.{self.name}.compiles")
        if nth > 1:
            _trace.REGISTRY.inc(f"compile.{self.name}.recompiles")
        _trace.emit({"type": "compile", "watch": self.name,
                     "sig": repr(sig), "nth": nth})

    def __call__(self, *args, **kwargs):
        self.calls += 1
        self._current = signature(args, kwargs)
        try:
            return self._jitted(*args, **kwargs)
        finally:
            self._current = None

    def n_compiles(self) -> int:
        return sum(self.compiles.values())

    def n_signatures(self) -> int:
        return len(self.compiles)

    def violations(self) -> list[tuple]:
        """Signatures traced more than once (recompile events)."""
        return [sig for sig, n in self.compiles.items() if n > 1]

    def assert_stable(self) -> None:
        bad = self.violations()
        if bad:
            raise AssertionError(
                f"compile watch '{self.name}': {len(bad)} signature(s) "
                f"recompiled — serve-step compile stability violated")


def wrap(fn: Callable, *, name: str, **jit_kwargs: Any):
    """``jax.jit(fn)`` with compile counting when observability is on;
    a plain ``jax.jit(fn)`` (no wrapper at all) when off."""
    import jax

    if not _trace.enabled():
        return jax.jit(fn, **jit_kwargs)
    return CompileWatch(fn, name, **jit_kwargs)


def all_watches() -> list[CompileWatch]:
    return list(_WATCHES)


def assert_all_stable() -> None:
    for w in _WATCHES:
        w.assert_stable()

"""repro.obs — lightweight, jit-compatible observability (DESIGN.md 13).

Five pieces, all gated on one switch (``REPRO_OBS`` env / ``use_obs``):

  trace          host-side spans, the metric registry, the JSONL sink
  injit          ``jax.debug.callback`` taps from inside traced code
  compile_watch  recompile sentinel for jitted entry points
  health         condition/residual/precision-drift monitors
  cost           modeled HBM bytes & flops as per-call gauges

Disabled (the default) is near-zero-cost BY CONSTRUCTION: spans are
no-op context managers, in-jit taps never enter the jaxpr (trace-time
gate), and ``compile_watch.wrap`` degenerates to a plain ``jax.jit`` —
compiled programs are bit-identical to a build without the wiring
(asserted in tests/test_obs.py).

Import as ``from repro.obs import trace as obs`` at call sites whose
namespace already uses the name ``trace`` (e.g. ``hyper/fit.py``).
"""
from . import trace, injit, compile_watch, cost, health  # noqa: F401
from .trace import (  # noqa: F401
    REGISTRY, Registry, configure, counter_value, emit, enabled, flush,
    gauge_value, reset, set_enabled, snapshot, span, use_obs,
)
from .health import HealthMonitor  # noqa: F401
from .injit import tap, tap_metrics  # noqa: F401

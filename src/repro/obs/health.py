"""Numerical-health monitors: cheap, cadence-sampled sanity probes.

Three probes over a live ``GPGState`` / ``GPGData``, each a handful of
O(N^2 D) host calls (never the dense (ND, ND) objects):

  * :func:`condition_proxy`    — (max/min valid Cholesky diagonal)^2, a
    free lower bound on cond(K1n) read straight off the cached ``L``.
    This is the early-warning signal for the degenerate-pivot fallback:
    nearly-collinear observations drive the smallest pivot toward the
    ``deg_thresh`` cliff long before the O(N^3) refactor actually fires.
  * :func:`solve_residual`     — relative residual ||A Z - rhs|| / ||rhs||
    of the cached representer solve, recomputed through ONE fused Gram
    MVM against the f32 masters.  A spot check that warm-started CG plus
    bordered-factor reuse has not silently drifted.
  * :func:`precision_drift`    — bf16-vs-f32 relative gradient-mean error
    on a few stored inputs, reusing the PR-5 oracle approach: the same
    ``posterior_batch`` evaluated at both stream precisions, f32 as the
    oracle.  Bounds what bf16 storage is currently costing the mean path.

:class:`HealthMonitor` samples all three at a configurable cadence and
publishes ``health.*`` gauges + one JSONL event per sample — attach one
with ``GPGState.attach_health`` and every ``extend()`` ticks it.

Imports from ``repro.core`` are deferred to call time so
``repro.obs.__init__`` (imported BY core.state) stays cycle-free.
"""
from __future__ import annotations

from typing import Optional

from repro.obs import trace as _trace


def condition_proxy(data) -> float:
    """(max/min valid diag of L)^2 — a lower bound on cond(K1n), free."""
    import jax.numpy as jnp

    n = int(data.count)
    if n < 1:
        return 1.0
    diag = jnp.diagonal(data.L)[:n]
    lo = float(jnp.min(diag))
    hi = float(jnp.max(diag))
    if lo <= 0.0:
        return float("inf")
    return (hi / lo) ** 2


def solve_residual(spec, data, *, noise: float = 0.0,
                   rhs=None) -> float:
    """Relative residual of the cached representer solve (default rhs: G).

    One fused Gram MVM on the f32 masters — the same operator ``_solve``
    iterated, applied once to the stored Z.  States solved against a
    custom RHS (flipped GP-X) should pass it explicitly.
    """
    import jax.numpy as jnp

    from repro.core.gram import GramFactors
    from repro.core.mvm import gram_matvec

    n = int(data.count)
    if n < 1:
        return 0.0
    mask = (jnp.arange(data.capacity) < data.count)[:, None]
    f = GramFactors(K1e=data.K1e, K2e=data.K2e,
                    Xt=jnp.where(mask, data.Xt, 0.0), lam=data.lam,
                    noise=float(noise), c=data.c)
    b = jnp.where(mask, data.G if rhs is None else rhs, 0.0)
    r = gram_matvec(f, data.Z, stationary=spec.is_stationary) - b
    denom = float(jnp.linalg.norm(b))
    if denom == 0.0:
        return 0.0
    return float(jnp.linalg.norm(jnp.where(mask, r, 0.0))) / denom


def precision_drift(state, Xq=None, *, n_points: int = 4) -> float:
    """Relative bf16-vs-f32 gradient-mean error at a few query points.

    The f32 evaluation is the oracle (PR-5 bench machinery, now samplable
    live); queries default to the first stored inputs — exactly where the
    posterior is best constrained and cancellation is harshest.
    """
    import jax.numpy as jnp

    n = int(state.n)
    if n < 1:
        return 0.0
    if Xq is None:
        Xq = state.X[: min(n, n_points)]
    f, Z = state.factors, state.Z
    from repro.core.query import posterior_batch

    lo = posterior_batch(state.spec, Xq, f, Z, precision="bf16")
    hi = posterior_batch(state.spec, Xq, f, Z, precision="f32")
    denom = float(jnp.linalg.norm(hi.grad))
    if denom == 0.0:
        return 0.0
    return float(jnp.linalg.norm(lo.grad - hi.grad)) / denom


class HealthMonitor:
    """Cadence-sampled health probes over a streaming ``GPGState``.

    ``tick(state)`` is called on every mutation (``GPGState`` does this
    when a monitor is attached); every ``cadence``-th tick runs the probes
    and publishes ``health.cond_k1n`` / ``health.solve_rel_residual`` /
    ``health.bf16_drift_rel`` gauges plus one ``{"type": "health"}``
    JSONL event.  ``drift`` costs two query evaluations — leave it off
    (default) unless bf16 storage is actually in play.
    """

    def __init__(self, cadence: int = 16, *, drift: bool = False):
        self.cadence = max(int(cadence), 1)
        self.drift = bool(drift)
        self.ticks = 0
        #: most recent probe dict — ``GPGState`` reads ``last["cond_k1n"]``
        #: to condition-scale its CG iteration budget (``_default_maxiter``)
        self.last: Optional[dict] = None

    def tick(self, state) -> Optional[dict]:
        if not _trace.enabled():
            return None
        self.ticks += 1
        _trace.REGISTRY.inc("health.ticks")
        if self.ticks % self.cadence != 0 or state.n < 1:
            return None
        return self.sample(state)

    def sample(self, state) -> dict:
        cond = condition_proxy(state.data)
        res = solve_residual(state.spec, state.data,
                             noise=state._noise_eff)
        out = {"cond_k1n": cond, "solve_rel_residual": res, "n": state.n}
        _trace.REGISTRY.inc("health.samples")
        _trace.REGISTRY.set_gauge("health.cond_k1n", cond)
        _trace.REGISTRY.set_gauge("health.solve_rel_residual", res)
        if self.drift:
            dr = precision_drift(state)
            out["bf16_drift_rel"] = dr
            _trace.REGISTRY.set_gauge("health.bf16_drift_rel", dr)
        _trace.emit({"type": "health", **out})
        self.last = out
        return out

"""Straggler mitigation: bounded gradient-skip via masked replica mean.

At 1000+ nodes the slowest data-parallel replica sets the step time. The
standard mitigation is to proceed without replicas that miss a deadline:
scale the gradient all-reduce by the LIVE replica count instead of the
nominal one. Inside shard_map that is a psum of (mask * grads) divided by
psum(mask) — a masked mean. Dropped replicas' examples are skipped (the
stateless pipeline makes the skip reproducible), and a bounded-staleness
counter forces a barrier if the same replica lags repeatedly.

This module is the mesh-side arithmetic; the liveness signal itself comes
from the launcher (deadline timers) or the test injector.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def masked_gradient_mean(grads, alive: Array, axis_name: str):
    """Mean of grads over live members of `axis_name`.

    grads: local gradient pytree (already summed over local examples);
    alive: scalar 0/1 for THIS member. Returns the pytree averaged over
    live members only; zero if none are alive (caller should skip step).
    """
    n_alive = jax.lax.psum(alive.astype(jnp.float32), axis_name)
    denom = jnp.maximum(n_alive, 1.0)

    def red(g):
        contrib = g.astype(jnp.float32) * alive.astype(jnp.float32)
        return jax.lax.psum(contrib, axis_name) / denom

    return jax.tree_util.tree_map(red, grads), n_alive

from .recovery import (FailureInjector, RecoveryConfig, SimulatedFailure,
                       run_with_recovery)
from .straggler import masked_gradient_mean

__all__ = ["FailureInjector", "RecoveryConfig", "SimulatedFailure",
           "run_with_recovery", "masked_gradient_mean"]

"""Fault-tolerant training loop: checkpoint/restart with failure injection.

`run_with_recovery` wraps a step function in the restart protocol a real
multi-pod job runs under a cluster scheduler:

  1. every `ckpt_every` steps, commit a checkpoint (two-phase, rotated);
  2. on failure (SimulatedFailure from the injector in tests; any
     exception tagged retryable in production) — restore the latest
     COMMITTED checkpoint, rebuild the data cursor (free: the pipeline is
     stateless in step), and resume;
  3. bounded retries guard against crash loops.

Because the data pipeline is a pure function of step and the train step is
deterministic, a recovered run is BIT-IDENTICAL to an uninterrupted one —
tests assert exactly that.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.runtime")


class SimulatedFailure(RuntimeError):
    """Injected node/step failure (tests and chaos drills)."""


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure at the given steps (once each)."""

    fail_at: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            # lazy: resilience imports this module (no top-level cycle)
            from repro.obs import trace as _trace

            _trace.REGISTRY.inc("resilience.faults_injected")
            _trace.REGISTRY.inc("resilience.injected.crash")
            _trace.emit({"type": "chaos", "kind": "crash", "step": step})
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RecoveryConfig:
    ckpt_dir: str
    ckpt_every: int = 10
    keep: int = 3
    max_restarts: int = 10
    async_ckpt: bool = False


def run_with_recovery(
    step_fn: Callable[[Any, Any, dict], tuple[Any, Any, dict]],
    batch_fn: Callable[[int], dict],
    params: Any,
    opt_state: Any,
    *,
    n_steps: int,
    config: RecoveryConfig,
    injector: Optional[FailureInjector] = None,
    shardings: tuple = (None, None),
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> tuple[Any, Any, dict]:
    """Run `n_steps` with checkpoint/restart. Returns (params, opt_state,
    stats). State trees must be restorable from their own structure."""
    mgr = CheckpointManager(config.ckpt_dir, keep=config.keep,
                            async_write=config.async_ckpt)
    stats = {"restarts": 0, "steps_replayed": 0, "checkpoints": 0}

    # resume if a committed checkpoint already exists
    start = 0
    latest = mgr.latest()
    if latest is not None:
        (params, opt_state), extras = _restore(mgr, latest, params, opt_state,
                                               shardings)
        start = latest
        log.info("resuming from step %d", start)
    else:
        # step-0 checkpoint: guarantees a failure before the first periodic
        # checkpoint restarts from the true initial state
        mgr.save(0, {"params": params, "opt": opt_state},
                 extras={"step": 0})
        stats["checkpoints"] += 1

    step = start
    restarts = 0
    while step < n_steps:
        try:
            batch = batch_fn(step)
            if injector is not None:
                injector.maybe_fail(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % config.ckpt_every == 0 or step == n_steps:
                mgr.save(step, {"params": params, "opt": opt_state},
                         extras={"step": step})
                stats["checkpoints"] += 1
        except SimulatedFailure as e:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > config.max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            latest = mgr.latest()
            if latest is None:      # cannot happen after the step-0 save
                raise RuntimeError(
                    "no committed checkpoint to restore") from e
            (params, opt_state), _ = _restore(mgr, latest, params, opt_state,
                                              shardings)
            stats["steps_replayed"] += step - latest
            from repro.resilience import guardrails as _guard

            _guard.record_recovery("crash", restored_step=latest)
            log.warning("%s -> restored step %d (was %d)", e, latest, step)
            step = latest
    mgr.wait()
    return params, opt_state, stats


def _restore(mgr: CheckpointManager, step: int, params, opt_state, shardings):
    import jax

    abstract = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
        {"params": params, "opt": opt_state})
    shard_tree = None
    if shardings[0] is not None:
        shard_tree = {"params": shardings[0], "opt": shardings[1]}
    tree, extras = mgr.restore(step, abstract, shard_tree)
    return (tree["params"], tree["opt"]), extras

"""Synthetic HMC targets (paper Eq. 30 / App. F.3).

Banana-shaped in (x1, x2), Gaussian in all other dimensions:
  E(x) = 1/2 (x1^2 + (a0 x1^2 + a1 x2 + a2)^2 + sum_{i>=3} a_i x_i^2),
  a = [2, -2, 2, ..., 2].
The rotated variant applies a random orthonormal matrix to the input so
the isotropic RBF surrogate is NOT axis-aligned with the target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def banana_energy(x: Array) -> Array:
    """Potential energy E(x) = -log p(x) (up to a constant); x: (D,)."""
    a0, a1, a2 = 2.0, -2.0, 2.0
    quad = x[0] ** 2 + (a0 * x[0] ** 2 + a1 * x[1] + a2) ** 2
    rest = 2.0 * jnp.sum(x[2:] ** 2)
    return 0.5 * (quad + rest)


def random_rotation(d: int, seed: int) -> Array:
    rng = np.random.RandomState(seed)
    q, _ = np.linalg.qr(rng.randn(d, d))
    return jnp.asarray(q)


def banana_energy_rotated(R: Array):
    def e(x: Array) -> Array:
        return banana_energy(R @ x)

    return e

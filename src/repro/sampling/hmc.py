"""Hamiltonian Monte Carlo with a leapfrog integrator (paper Sec. 4.3).

The sampler is fully jitted: the leapfrog trajectory is a lax.scan and the
chain itself a lax.scan over proposals, so long chains cost one dispatch.
Gradient evaluations go through a caller-supplied function so the same
driver runs plain HMC (exact grad) and GPG-HMC (surrogate grad).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def leapfrog(grad_fn: Callable[[Array], Array], x: Array, p: Array,
             eps: float, steps: int) -> tuple[Array, Array]:
    """T leapfrog steps of size eps. Returns (x_new, p_new)."""
    p = p - 0.5 * eps * grad_fn(x)

    def body(carry, _):
        x_, p_ = carry
        x_ = x_ + eps * p_
        g = grad_fn(x_)
        return (x_, p_ - eps * g), None

    (x, p), _ = jax.lax.scan(body, (x, p), None, length=steps - 1)
    x = x + eps * p
    p = p - 0.5 * eps * grad_fn(x)
    return x, p


class HMCResult(NamedTuple):
    samples: Array        # (n, D)
    accept_rate: Array
    energies: Array       # (n,)


@partial(jax.jit, static_argnames=("energy_fn", "grad_fn", "n_samples",
                                   "steps"))
def hmc(
    energy_fn: Callable[[Array], Array],
    x0: Array,
    key: Array,
    *,
    n_samples: int,
    eps: float,
    steps: int,
    mass: float = 1.0,
    grad_fn: Callable[[Array], Array] | None = None,
) -> HMCResult:
    """Standard HMC. grad_fn defaults to jax.grad(energy_fn) — pass a GP
    surrogate to get Alg. 3 (the acceptance test still uses the TRUE
    energy, so samples remain valid draws from e^{-E})."""
    if grad_fn is None:
        grad_fn = jax.grad(energy_fn)

    def step(carry, k):
        x, e_x = carry
        k1, k2 = jax.random.split(k)
        p = jax.random.normal(k1, x.shape, x.dtype) * jnp.sqrt(mass)
        h0 = e_x + 0.5 * jnp.sum(p * p) / mass
        x_new, p_new = leapfrog(grad_fn, x, p, eps, steps)
        e_new = energy_fn(x_new)
        h1 = e_new + 0.5 * jnp.sum(p_new * p_new) / mass
        accept = jax.random.uniform(k2) < jnp.exp(jnp.minimum(h0 - h1, 0.0))
        x = jnp.where(accept, x_new, x)
        e_x = jnp.where(accept, e_new, e_x)
        return (x, e_x), (x, accept, e_x)

    keys = jax.random.split(key, n_samples)
    (_, _), (xs, accepts, es) = jax.lax.scan(step, (x0, energy_fn(x0)), keys)
    return HMCResult(samples=xs, accept_rate=jnp.mean(accepts), energies=es)

"""GPG-HMC: HMC with a GP gradient surrogate (paper Alg. 3 / Sec. 5.3).

Training procedure (Sec. 5.3): budget N = floor(sqrt(D)).
  Phase 1 — run plain HMC (true gradients) until N/2 spatially diverse
            points (pairwise scaled distance r > 1, i.e. more than one
            kernel lengthscale apart) are collected.
  Phase 2 — switch to the surrogate for leapfrog; whenever the chain
            reaches a location far from all training points, query the
            TRUE gradient there and recondition, until the budget fills.
  Phase 3 — pure surrogate sampling. The Metropolis test always evaluates
            the true energy E, so the samples remain valid draws of e^-E
            regardless of surrogate quality (the paper's key point: the
            surrogate only costs acceptance rate, never correctness).

The surrogate is the paper's exact gradient-GP held in ONE incrementally
maintained ``repro.core.GPGState``: each recondition is a bordered factor
update + warm-started re-solve (O(N^2 D), never the O(N^6) dense inner
refactorization), and every leapfrog gradient prediction is a batched
query against the cached solve — precisely the serving machinery of
core/state.py + core/query.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import GPGState, cross_grad_matvec
from repro.hyper import HyperParams
from repro.obs import trace as _obs

from .hmc import leapfrog

Array = jnp.ndarray


def _as_hypers(hypers, lengthscale2, *, noise: float = 1e-8) -> HyperParams:
    """Normalize the hyperparameter inputs to ONE ``HyperParams``.

    ``lengthscale2`` is the legacy loose-float spelling (kept so existing
    call sites run unchanged); ``hypers`` — e.g. a ``repro.hyper.fit``
    result — wins when both are given.
    """
    if hypers is not None:
        if not isinstance(hypers, HyperParams):
            raise TypeError(f"hypers must be a HyperParams, got "
                            f"{type(hypers).__name__}")
        return hypers
    if lengthscale2 is None:
        raise TypeError("need either hypers=HyperParams(...) or "
                        "lengthscale2=<float>")
    return HyperParams.create(lengthscale2=lengthscale2, noise=noise)


@dataclasses.dataclass
class GradientSurrogate:
    """Conditioned gradient-GP surrogate, backed by a streaming GPGState.

    ``predictor()`` snapshots the current factors/Z into a pure closure
    (jit-friendly leapfrog grad_fn); queries perform zero solves.
    """

    state: GPGState

    @property
    def X(self) -> Array:
        return self.state.X

    @property
    def G(self) -> Array:
        return self.state.G

    @property
    def Z(self) -> Array:
        return self.state.Z

    @property
    def lam(self) -> float:
        return float(self.state.data.lam)

    @property
    def hypers(self) -> HyperParams:
        """The surrogate's current hypers (shared container, one source of
        truth with optim/ and serve/)."""
        return self.state.hypers

    def predictor(self) -> Callable[[Array], Array]:
        spec, f, Z = self.state.spec, self.state.factors, self.state.Z

        def predict(x: Array) -> Array:
            return cross_grad_matvec(spec, x[None], f, Z)[0]

        return predict

    def predict(self, x: Array) -> Array:
        return self.predictor()(x)


def condition_surrogate(X: Array, G: Array,
                        hypers: HyperParams | float | None = None,
                        noise: float = 1e-8) -> GradientSurrogate:
    """Bulk-condition a surrogate (one solve); stream further points with
    ``surrogate.state.extend``.  ``hypers`` is a ``HyperParams`` (preferred)
    or the legacy bare Lambda float."""
    if hypers is None:
        raise TypeError("condition_surrogate needs hypers=HyperParams(...) "
                        "or the legacy bare Lambda float")
    if not isinstance(hypers, HyperParams):
        hypers = HyperParams.from_lam(float(hypers), noise=noise)
    st = GPGState.from_data("rbf", X, G, lam=hypers.lam,
                            noise=float(hypers.noise),
                            signal=float(hypers.signal))
    return GradientSurrogate(state=st)


@partial(jax.jit, static_argnames=("energy_fn", "grad_fn", "steps"))
def _hmc_step(energy_fn, grad_fn, x, e_x, key, eps, steps, mass):
    k1, k2 = jax.random.split(key)
    p = jax.random.normal(k1, x.shape, x.dtype) * jnp.sqrt(mass)
    h0 = e_x + 0.5 * jnp.sum(p * p) / mass
    x_new, p_new = leapfrog(grad_fn, x, p, eps, steps)
    e_new = energy_fn(x_new)
    h1 = e_new + 0.5 * jnp.sum(p_new * p_new) / mass
    accept = jax.random.uniform(k2) < jnp.exp(jnp.minimum(h0 - h1, 0.0))
    x = jnp.where(accept, x_new, x)
    e_x = jnp.where(accept, e_new, e_x)
    return x, e_x, accept, x_new


class GPGHMCResult(NamedTuple):
    samples: Array
    accept_rate: float
    n_true_grad_calls: int      # gradient queries spent on training
    n_train_iters: int          # HMC iterations before pure-surrogate mode
    surrogate: GradientSurrogate


def _min_r(x: Array, X: Array, lam: float) -> float:
    d = X - x[None]
    return float(jnp.min(jnp.sum(d * d, axis=1)) * lam)


def gpg_hmc(
    energy_fn: Callable[[Array], Array],
    x0: Array,
    key: Array,
    *,
    n_samples: int,
    eps: float,
    steps: int,
    budget: int,
    hypers: HyperParams | None = None,
    lengthscale2: float | None = None,
    refit_surrogate: bool = False,
    mass: float = 1.0,
    max_train_iters: int = 5000,
) -> GPGHMCResult:
    """Alg. 3.  Hyperparameters come in as ONE ``HyperParams`` container
    (``lengthscale2=`` is the legacy float spelling); ``refit_surrogate``
    re-fits them by exact MLL ascent on the phase-1 training set right
    after the cold solve (``GPGState.refit``), so phases 2-3 run on
    evidence-optimal hypers instead of the ell^2 = 0.4 D heuristic."""
    hp = _as_hypers(hypers, lengthscale2)
    grad_true = jax.grad(energy_fn)
    lam = float(hp.lam)
    x = jnp.asarray(x0)
    e_x = energy_fn(x)
    st = GPGState("rbf", x.shape[0], capacity=max(budget, 2), lam=lam,
                  noise=float(hp.noise), signal=float(hp.signal))
    st.extend(x, grad_true(x), solve=False)
    n_true = 1
    it = 0

    # Phase 1: plain HMC until budget/2 diverse points; the surrogate is
    # not queried yet, so observations append factor borders without solves
    with _obs.span("hmc.phase1"):
        while st.n < max(budget // 2, 2) and it < max_train_iters:
            key, k = jax.random.split(key)
            x, e_x, _, _ = _hmc_step(energy_fn, grad_true, x, e_x, k, eps,
                                     steps, mass)
            it += 1
            if _min_r(x, st.X, lam) > 1.0:
                st.extend(x, grad_true(x), solve=False)
                n_true += 2  # leapfrog used true grads anyway; count the
                # query

        st.resolve(st.G)              # first (and only cold) solve
        if refit_surrogate and st.n >= 2:
            # fit on the diverse phase-1 set; refit() refactors +
            # re-solves, and the distance gate below follows the fitted
            # lengthscale
            st.refit(steps=60)
            lam = float(st.data.lam)
    sur = GradientSurrogate(state=st)
    grad_sur = sur.predictor()

    # Phase 2: surrogate leapfrog; true-grad queries only at new locations.
    # Crucially the PROPOSAL endpoint is checked too: a rejected proposal
    # that flew far from the training set is exactly where the surrogate is
    # wrong, so that is where the next true gradient is spent. Without this
    # the chain can deadlock (all proposals rejected -> no new locations).
    # Each recondition is ONE bordered extend + warm re-solve on the state.
    n_recond = 0
    with _obs.span("hmc.phase2"):
        while st.n < budget and it < max_train_iters:
            key, k = jax.random.split(key)
            x, e_x, _, x_prop = _hmc_step(energy_fn, grad_sur, x, e_x, k,
                                          eps, steps, mass)
            it += 1
            added = False
            for cand in (x, x_prop):
                if st.n < budget and _min_r(cand, st.X, lam) > 1.0:
                    st.extend(cand, grad_true(cand))
                    n_true += 1
                    added = True
            if added:
                n_recond += 1
                grad_sur = sur.predictor()

    # Phase 3: pure surrogate sampling (jitted chain)
    def step(carry, k):
        x_, e_ = carry
        x_, e_, acc, _ = _hmc_step(energy_fn, grad_sur, x_, e_, k, eps,
                                   steps, mass)
        return (x_, e_), (x_, acc)

    keys = jax.random.split(key, n_samples)
    with _obs.span("hmc.phase3", n_samples=n_samples):
        (_, _), (xs, accepts) = jax.lax.scan(step, (x, e_x), keys)
        accepts = jax.block_until_ready(accepts)
    if _obs.enabled():
        _obs.REGISTRY.inc("hmc.true_grad_calls", n_true)
        _obs.REGISTRY.inc("hmc.reconditions", n_recond)
        _obs.REGISTRY.set_gauge("hmc.accept_rate",
                                float(jnp.mean(accepts)))
        _obs.REGISTRY.set_gauge("hmc.train_iters", it)
    return GPGHMCResult(
        samples=xs,
        accept_rate=float(jnp.mean(accepts)),
        n_true_grad_calls=n_true,
        n_train_iters=it,
        surrogate=sur,
    )

from .hmc import HMCResult, hmc, leapfrog
from .gpg_hmc import (GPGHMCResult, GradientSurrogate, condition_surrogate,
                      gpg_hmc)
from .targets import banana_energy, banana_energy_rotated, random_rotation

__all__ = ["HMCResult", "hmc", "leapfrog", "GPGHMCResult",
           "GradientSurrogate", "condition_surrogate", "gpg_hmc",
           "banana_energy", "banana_energy_rotated", "random_rotation"]

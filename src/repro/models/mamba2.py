"""Mamba2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

Faithful to the minimal SSD algorithm of Dao & Gu (arXiv:2405.21060):
within-chunk computation is quadratic attention-like (einsums against a
causal decay mask), across chunks a state of size (heads, head_dim, d_state)
is carried by a lax.scan. Decode is a single recurrent state update —
the property that makes long_500k trivial for SSM archs.

Shapes: d_inner = expand * d_model; n_heads = d_inner / ssm_head_dim;
state per layer = (conv ring (B, conv_width-1, conv_channels),
                   ssm state (B, n_heads, head_dim, d_state)).

Sharding: SSM heads are the TP axis (logical "ssm_heads" -> 'model');
B/C projections (d_state-sized, shared across heads: n_groups=1) are
replicated.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rms_norm, shard_activation

Array = jnp.ndarray


def _dims(cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    n_heads = d_inner // cfg.ssm_head_dim
    return d, d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(rng, cfg: ModelConfig, *, d_model: int | None = None):
    d, d_inner, h, p_, n = _dims(cfg, d_model)
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 8)
    p, s = {}, {}
    p["in_z"], s["in_z"] = dense_init(ks[0], d, d_inner, dt, ("embed", "ssm_heads"))
    p["in_x"], s["in_x"] = dense_init(ks[1], d, d_inner, dt, ("embed", "ssm_heads"))
    p["in_B"], s["in_B"] = dense_init(ks[2], d, n, dt, ("embed", "state"))
    p["in_C"], s["in_C"] = dense_init(ks[3], d, n, dt, ("embed", "state"))
    p["in_dt"], s["in_dt"] = dense_init(ks[4], d, h, dt, ("embed", "ssm_heads"))
    # conv over channels [x | B | C]
    cw = cfg.conv_width
    p["conv_w"] = (jax.random.normal(ks[5], (cw, d_inner + 2 * n), jnp.float32)
                   / jnp.sqrt(cw)).astype(dt)
    s["conv_w"] = ("conv", "ssm_heads")
    p["conv_b"] = jnp.zeros((d_inner + 2 * n,), dt)
    s["conv_b"] = ("ssm_heads",)
    # per-head scalars: A (negative), D (skip), dt bias
    p["A_log"] = jnp.zeros((h,), jnp.float32)          # A = -exp(A_log)
    s["A_log"] = ("ssm_heads",)
    p["D"] = jnp.ones((h,), jnp.float32)
    s["D"] = ("ssm_heads",)
    p["dt_bias"] = jnp.full((h,), -2.0, jnp.float32)   # softplus ~ 0.12
    s["dt_bias"] = ("ssm_heads",)
    p["norm_w"] = jnp.ones((d_inner,), dt)
    s["norm_w"] = ("ssm_heads",)
    p["out"], s["out"] = dense_init(ks[6], d_inner, d, dt, ("ssm_heads", "embed"))
    return p, s


def _causal_conv(u: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: u (B, L, C), w (W, C) -> (B, L, C)."""
    width = w.shape[0]
    u_pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(width):                 # tiny static loop (W = 4)
        out = out + u_pad[:, i:i + u.shape[1], :] * w[i]
    return out + b


def _segsum(a: Array) -> Array:
    """a (..., q) -> (..., q, q) lower-tri cumulative sums; -inf above diag."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


class MambaState(NamedTuple):
    conv: Array   # (B, conv_width-1, d_inner + 2*d_state)
    ssm: Array    # (B, n_heads, head_dim, d_state) f32


def init_mamba_state(cfg: ModelConfig, batch: int, *,
                     d_model: int | None = None, dtype=None) -> MambaState:
    d, d_inner, h, p_, n = _dims(cfg, d_model)
    dt = dtype or cfg.compute_dtype
    return MambaState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_inner + 2 * n), dt),
        ssm=jnp.zeros((batch, h, p_, n), jnp.float32),
    )


def _project(p, cfg: ModelConfig, x: Array, d_inner: int, n: int, h: int):
    z = x @ p["in_z"]
    xbc = jnp.concatenate([x @ p["in_x"], x @ p["in_B"], x @ p["in_C"]],
                          axis=-1)
    dt_raw = (x @ p["in_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    return z, xbc, dt


def mamba_forward(p, cfg: ModelConfig, x: Array, *,
                  d_model: int | None = None, return_state: bool = False):
    """Full-sequence SSD. x: (B, L, D) -> (B, L, D) [, final MambaState].

    The final state falls out of the chunk scan's carry for free (padding
    is state-neutral: padded dt = 0 -> decay 1, contribution 0), so prefill
    hands decode an exact state with zero extra passes.
    """
    d, d_inner, h, hp, n = _dims(cfg, d_model)
    b, length, _ = x.shape
    z, xbc_raw, dt = _project(p, cfg, x, d_inner, n, h)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_inner].reshape(b, length, h, hp)
    Bm = xbc[..., d_inner:d_inner + n].astype(jnp.float32)
    Cm = xbc[..., d_inner + n:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                       # (h,)

    q = min(cfg.ssm_chunk, length)
    nc = -(-length // q)
    pad = nc * q - length
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    # chunked shapes
    xc = xs.reshape(b, nc, q, h, hp).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)
    dtc = dt.reshape(b, nc, q, h)
    dtx = xc * dtc[..., None]                      # x * dt
    dta = dtc * A                                  # A * dt, (b,nc,q,h)

    a_cum = jnp.cumsum(dta, axis=2)                # (b,nc,q,h)
    L = jnp.exp(_segsum(dta.transpose(0, 1, 3, 2)))        # (b,nc,h,q,q)
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", Cc, Bc, L, dtx)

    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)    # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_states, dtx)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])              # (b,nc,h)

    def body(S, xs_c):
        st_c, dec_c = xs_c                                 # (b,h,p,n), (b,h)
        S_new = S * dec_c[..., None, None] + st_c
        return S_new, S                                    # emit state BEFORE chunk

    S0 = jnp.zeros((b, h, hp, n), jnp.float32)
    S_final, S_prev = jax.lax.scan(
        body, S0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)               # (b,nc,h,p,n)

    state_decay = jnp.exp(a_cum)                           # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, S_prev, state_decay)

    y = (y_diag + y_off).reshape(b, nc * q, h, hp)[:, :length]
    y = y + xs[:, :length].astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, length, d_inner).astype(x.dtype)
    y = shard_activation(y, "ffh")
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out"]
    if not return_state:
        return out
    cw = cfg.conv_width
    conv_hist = xbc_raw[:, -(cw - 1):, :]
    if length < cw - 1:
        conv_hist = jnp.pad(xbc_raw, ((0, 0), (cw - 1 - length, 0), (0, 0)))
    state = MambaState(conv=conv_hist.astype(cfg.compute_dtype), ssm=S_final)
    return out, state


def mamba_decode(p, cfg: ModelConfig, x1: Array, state: MambaState, *,
                 d_model: int | None = None):
    """One-token decode. x1: (B, 1, D) -> (y (B,1,D), new state)."""
    d, d_inner, h, hp, n = _dims(cfg, d_model)
    b = x1.shape[0]
    z, xbc, dt = _project(p, cfg, x1, d_inner, n, h)       # (B,1,*)
    # conv over ring of last (W-1) inputs + current
    hist = jnp.concatenate([state.conv, xbc.astype(state.conv.dtype)], axis=1)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)                           # (B, C)
    xs = xbc1[:, :d_inner].reshape(b, h, hp)
    B1 = xbc1[:, d_inner:d_inner + n]
    C1 = xbc1[:, d_inner + n:]
    dt1 = dt[:, 0]                                         # (B, h)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A)                               # (B, h)
    dtx = xs * dt1[..., None]                              # (B,h,p)
    ssm = state.ssm * decay[..., None, None] + \
        jnp.einsum("bn,bhp->bhpn", B1, dtx)
    y = jnp.einsum("bn,bhpn->bhp", C1, ssm) + xs * p["D"][:, None]
    y = y.reshape(b, 1, d_inner).astype(x1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    new_state = MambaState(conv=hist[:, 1:], ssm=ssm)
    return y @ p["out"], new_state

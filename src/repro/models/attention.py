"""GQA attention: training/prefill (chunked online-softmax), decode w/ caches.

Variants covered (per assigned archs):
  * grouped-query attention with arbitrary (n_heads, n_kv_heads, head_dim)
  * RoPE styles full / half / mrope (rope.py)
  * optional QKV bias (qwen2.5 / qwen2-vl)
  * causal, sliding-window-causal (gemma3 local layers), and full
    (encoder / cross-attention) masking
  * decode against a full KV cache or a ring-buffer window cache

LAYOUT (the §Perf-critical design decision): queries live in the 5-D GQA
layout (B, S, Hk, G, hd) from projection to output — weights are stored
4-D (D, Hk, G, hd) so NO sharded axis is ever reshaped. The first
implementation reshaped (B,S,H,hd) -> (B,S,Hk,G,hd) inside the chunk scan;
with H sharded on 'model' GSPMD could only satisfy that by replicating —
an all-gather of the f32 accumulator EVERY chunk step, measured at
30 TB/device for qwen2.5-32b prefill_32k (EXPERIMENTS.md §Perf).

Sharding of the GQA axes is config-adaptive: the Hk axis is sharded when
it pads better than G (qwen: Hk=8 pads 2x vs G=5 -> 3.2x), else G
(chatglm: Hk=2 would pad 8x, G=16 pads 1x).

Memory-efficient path: for long sequences the softmax is computed online
over KV chunks with a lax.scan (flash-attention structure in pure JAX,
carries pinned to the heads layout) so prefill_32k never materializes an
(S, S) score matrix.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, shard_activation
from .rope import apply_rope

Array = jnp.ndarray

_NEG_INF = -1e30
_CHUNK = 1024          # KV chunk for the online-softmax scan
_DENSE_MAX = 2048      # use one-shot dense attention below this seq length

_TP = 16               # production TP degree used for the padding heuristic


def _gqa_dims(cfg: ModelConfig, n_heads=None, n_kv_heads=None):
    h = n_heads or cfg.n_heads
    hk = n_kv_heads or cfg.n_kv_heads
    return hk, h // hk, cfg.resolved_head_dim


def _pad_waste(n: int, tp: int = _TP) -> float:
    return (-(-n // tp) * tp) / n


def gqa_shard_axis(cfg: ModelConfig, n_heads=None, n_kv_heads=None) -> str:
    """'hk' or 'g' — whichever GQA axis pads less on the TP degree."""
    hk, g, _ = _gqa_dims(cfg, n_heads, n_kv_heads)
    return "hk" if _pad_waste(hk) <= _pad_waste(g) else "g"


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attn(rng, cfg: ModelConfig, *, d_model: int | None = None,
              n_heads: int | None = None, n_kv_heads: int | None = None):
    d = d_model or cfg.d_model
    hk, g, hd = _gqa_dims(cfg, n_heads, n_kv_heads)
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 4)
    scale = 1.0 / jnp.sqrt(d)
    axis = gqa_shard_axis(cfg, n_heads, n_kv_heads)
    hk_ax = "kv_heads" if axis == "hk" else None
    g_ax = None if axis == "hk" else "heads"

    def mk(rng_, shape):
        return (jax.random.normal(rng_, shape, jnp.float32) * scale).astype(dt)

    p, s = {}, {}
    p["wq"] = mk(ks[0], (d, hk, g, hd))
    s["wq"] = ("embed", hk_ax, g_ax, None)
    p["wk"] = mk(ks[1], (d, hk, hd))
    s["wk"] = ("embed", "kv_heads", None)
    p["wv"] = mk(ks[2], (d, hk, hd))
    s["wv"] = ("embed", "kv_heads", None)
    p["wo"] = (jax.random.normal(ks[3], (hk, g, hd, d), jnp.float32) /
               jnp.sqrt(hk * g * hd)).astype(dt)
    s["wo"] = (hk_ax, g_ax, None, "embed")
    if cfg.qkv_bias:
        p["bq"], s["bq"] = jnp.zeros((hk, g, hd), dt), (hk_ax, g_ax, None)
        p["bk"], s["bk"] = jnp.zeros((hk, hd), dt), ("kv_heads", None)
        p["bv"], s["bv"] = jnp.zeros((hk, hd), dt), ("kv_heads", None)
    return p, s


def _q_kind(cfg, n_heads=None, n_kv_heads=None) -> str:
    return "q5_hk" if gqa_shard_axis(cfg, n_heads, n_kv_heads) == "hk" \
        else "q5_g"


def _project_qkv(p, cfg: ModelConfig, x: Array, n_heads=None,
                 n_kv_heads=None):
    """x (B,S,D) -> q (B,S,Hk,G,hd), k/v (B,S,Hk,hd). No head reshapes."""
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math (5-D GQA layout)
# ---------------------------------------------------------------------------


def _scores(q: Array, k: Array) -> Array:
    """q (B,Sq,Hk,G,hd), k (B,Sk,Hk,hd) -> (B,Hk,G,Sq,Sk) f32."""
    hd = q.shape[-1]
    sc = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                    k.astype(jnp.float32))
    return sc / jnp.sqrt(hd).astype(jnp.float32)


def _attend(w: Array, v: Array) -> Array:
    """w (B,Hk,G,Sq,Sk) f32, v (B,Sk,Hk,hd) -> (B,Sq,Hk,G,hd) f32."""
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))


def _mask_bias(q_pos: Array, k_pos: Array, kind: str, window: int) -> Array:
    """(Sq, Sk) additive bias: 0 allowed / -inf masked."""
    if kind == "full":
        return jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    allowed = k_pos[None, :] <= q_pos[:, None]
    if kind == "window":
        allowed &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(allowed, 0.0, _NEG_INF)


def _dense_attention(q, k, v, q_pos, k_pos, kind, window):
    sc = _scores(q, k) + _mask_bias(q_pos, k_pos, kind, window)[None, None,
                                                                None]
    w = jax.nn.softmax(sc, axis=-1)
    return _attend(w, v).astype(q.dtype)


def _chunked_attention(q, k, v, q_pos, k_pos, kind, window, qkind,
                       chunk=_CHUNK):
    """Online-softmax over KV chunks (flash structure; O(Sq*chunk) memory).

    Carries (m, l, acc) are PINNED to the GQA layout via sharding
    constraints — without this GSPMD may choose a replicated while-loop
    state and all-gather the accumulator every chunk step (§Perf)."""
    b, sq, hk, g, hd = q.shape
    sk = k.shape[1]
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)  # masked out
    kc = k.reshape(b, n_chunks, chunk, hk, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hk, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    def pin(m, l, acc):
        m = shard_activation(m, qkind + "_stats")
        l = shard_activation(l, qkind + "_stats")
        acc = shard_activation(acc, qkind)
        return m, l, acc

    def body(carry, xs):
        m, l, acc = carry             # (B,Hk,G,Sq) x2, (B,Sq,Hk,G,hd) f32
        k_i, v_i, p_i = xs
        sc = _scores(q, k_i) + _mask_bias(q_pos, p_i, kind,
                                          window)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + jnp.sum(pr, axis=-1)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + \
            _attend(pr, v_i)
        return pin(m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, hk, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, pin(m0, l0, a0), (kc, vc, pc))
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / denom).astype(q.dtype)


def attention_core(q, k, v, q_pos, k_pos, *, kind: str = "causal",
                   window: int = 0, qkind: str = "q5_hk") -> Array:
    """Dispatch dense vs chunked based on KV length."""
    if k.shape[1] <= _DENSE_MAX:
        return _dense_attention(q, k, v, q_pos, k_pos, kind, window)
    return _chunked_attention(q, k, v, q_pos, k_pos, kind, window, qkind)


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer KV cache. For window layers, k/v are ring buffers of size W
    and `pos` entries store absolute positions (-1 = empty)."""

    k: Array            # (B, S_cache, Hk, hd)
    v: Array            # (B, S_cache, Hk, hd)
    pos: Array          # (B, S_cache) int32 absolute positions, -1 empty


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               window: int = 0, n_kv_heads: int | None = None,
               dtype=None) -> KVCache:
    hk = n_kv_heads or cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    size = min(window, max_len) if window else max_len
    dt = dtype or cfg.compute_dtype
    return KVCache(
        k=jnp.zeros((batch, size, hk, hd), dt),
        v=jnp.zeros((batch, size, hk, hd), dt),
        pos=jnp.full((batch, size), -1, jnp.int32),
    )


def _merge_heads(out: Array, wo: Array) -> Array:
    """(B,S,Hk,G,hd) x (Hk,G,hd,D) -> (B,S,D)."""
    return jnp.einsum("bqkgd,kgdm->bqm", out, wo)


def attn_forward(p, cfg: ModelConfig, x: Array, positions: Array, *,
                 kind: str = "causal", window: int = 0,
                 n_heads: int | None = None, n_kv_heads: int | None = None,
                 return_kv: bool = False):
    """Full-seq attention. positions: (B, S) or (B, 3, S) for mrope."""
    qkind = _q_kind(cfg, n_heads, n_kv_heads)
    q, k, v = _project_qkv(p, cfg, x, n_heads, n_kv_heads)
    pos_1d = positions[:, 0] if positions.ndim == 3 else positions
    if kind != "full" or cfg.family == "encdec":
        q, k = apply_rope(q, k, positions, style=cfg.rope_style,
                          theta=cfg.rope_theta)
    q = shard_activation(q, qkind)
    k = shard_activation(k, "kv4")
    # positions are identical across batch rows in our pipelines: use row 0
    qp = pos_1d[0]
    out = attention_core(q, k, v, qp, qp, kind=kind, window=window,
                         qkind=qkind)
    out = shard_activation(out, qkind)
    y = _merge_heads(out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def cross_attn_forward(p, cfg: ModelConfig, x: Array, enc_k: Array,
                       enc_v: Array, *, n_heads: int | None = None):
    """Decoder cross-attention against precomputed encoder K/V (no mask)."""
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    sq_pos = jnp.arange(x.shape[1])
    sk_pos = jnp.arange(enc_k.shape[1])
    out = attention_core(q, enc_k, enc_v, sq_pos, sk_pos, kind="full",
                         qkind=_q_kind(cfg, n_heads))
    return _merge_heads(out, p["wo"])


def encode_kv(p, cfg: ModelConfig, enc_out: Array,
              n_kv_heads: int | None = None):
    """Project encoder output to cross-attention K/V once (cached)."""
    k = jnp.einsum("bsd,dkh->bskh", enc_out, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# Decode (single token) against a cache
# ---------------------------------------------------------------------------


def attn_decode(p, cfg: ModelConfig, x1: Array, pos: Array, cache: KVCache, *,
                window: int = 0, n_heads: int | None = None,
                n_kv_heads: int | None = None):
    """One-token decode. x1: (B, 1, D); pos: (B,) absolute position.

    Writes the new K/V into the cache (ring-indexed if window) and attends
    over all valid entries. Returns (y (B,1,D), new_cache).
    """
    b = x1.shape[0]
    q, k, v = _project_qkv(p, cfg, x1, n_heads, n_kv_heads)
    pos_b1 = pos[:, None]                              # (B, 1)
    if cfg.rope_style == "mrope":
        rp = jnp.broadcast_to(pos_b1[:, None, :], (b, 3, 1))
        q, k = apply_rope(q, k, rp, style="mrope", theta=cfg.rope_theta)
    else:
        q, k = apply_rope(q, k, pos_b1, style=cfg.rope_style,
                          theta=cfg.rope_theta)

    size = cache.k.shape[1]
    slot = (pos % size) if window else jnp.minimum(pos, size - 1)

    def write(buf, new):
        # buf (B, S, Hk, hd), new (B, 1, Hk, hd): scatter at per-row slot
        return jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(bb, nn, ss, 0)
        )(buf, new.astype(buf.dtype), slot)

    new_cache = KVCache(
        k=write(cache.k, k),
        v=write(cache.v, v),
        pos=jax.vmap(
            lambda pp, ss, vv: jax.lax.dynamic_update_slice_in_dim(
                pp, vv[None], ss, 0)
        )(cache.pos, slot, pos.astype(jnp.int32)),
    )

    # scores against the whole cache; invalid (-1) and out-of-window entries
    # are masked via the stored absolute positions.
    sc = _scores(q, new_cache.k)                       # (B, Hk, G, 1, S)
    kpos = new_cache.pos                               # (B, S)
    valid = kpos >= 0
    valid &= kpos <= pos[:, None]
    if window:
        valid &= kpos > (pos[:, None] - window)
    sc = jnp.where(valid[:, None, None, None, :], sc, _NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = _attend(w, new_cache.v).astype(x1.dtype)
    return _merge_heads(out, p["wo"]), new_cache

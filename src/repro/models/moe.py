"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, EP sharding.

Covers both assigned MoE archs:
  * kimi-k2-1t-a32b  — 384 routed experts, top-8, 1 shared expert
  * deepseek-moe-16b — 64 routed experts, top-6, 2 shared experts
    (fine-grained experts: d_ff per expert is small; shared experts run
    densely for every token)

Dispatch is GShard/Switch-style with a capacity factor: tokens pick top-k
experts, each expert processes at most C = cf * T * k / E tokens, overflow
is dropped (contributes zero — the residual connection carries the token).
Dispatch/combine are einsums against a (T, E, C) one-hot tensor — the
XLA-friendly dense formulation whose sharded lowering produces the
all-to-all pattern on the `model` (expert) axis.

Expert weights have logical axes ("experts", "embed", "expert_mlp") so EP
maps experts -> 'model' while each expert's FFN stays unsharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, shard_activation, swiglu
from .mlp import init_mlp, mlp_forward

Array = jnp.ndarray


def init_moe(rng, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 5)
    scale = 1.0 / jnp.sqrt(d)
    p, s = {}, {}
    p["router"] = (jax.random.normal(ks[0], (d, e), jnp.float32) * scale
                   ).astype(jnp.float32)           # router kept in f32
    s["router"] = ("embed", "experts")

    def ew(rng_, shape):
        return (jax.random.normal(rng_, shape, jnp.float32) * scale).astype(dt)

    p["w_gate"] = ew(ks[1], (e, d, f))
    p["w_up"] = ew(ks[2], (e, d, f))
    p["w_down"] = (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(dt)
    s["w_gate"] = ("experts", "embed", "expert_mlp")
    s["w_up"] = ("experts", "embed", "expert_mlp")
    s["w_down"] = ("experts", "expert_mlp", "embed")
    if cfg.n_shared_experts:
        p["shared"], s["shared"] = init_mlp(
            ks[4], cfg, d_ff=f * cfg.n_shared_experts)
    return p, s


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.n_experts)
    return max(cap, 4)


def moe_forward(p, cfg: ModelConfig, x: Array):
    """x: (B, S, D) -> (B, S, D); aux load-balance loss returned too."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    f_e = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (t * k)) * k
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)

    cap = _capacity(cfg, t)
    if cfg.moe_impl == "gather":
        y = _dispatch_gather(p, cfg, xt, expert_idx, gate_vals, cap)
        if cfg.n_shared_experts:
            y = y + mlp_forward(p["shared"], xt)
        return y.reshape(b, s, d), aux

    # ---- dense one-hot baseline (GShard formulation) ----
    pos_onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (T,k,E)
    # rank of token t among tokens routed to the same expert (per k-slot,
    # cumulative over flattened (k, T) priority order: slot 0 first)
    prio = pos_onehot.transpose(1, 0, 2).reshape(k * t, e)   # (k*T, E)
    ranks = jnp.cumsum(prio, axis=0) - prio                  # 0-based
    ranks = ranks.reshape(k, t, e).transpose(1, 0, 2)        # (T, k, E)
    within = jnp.sum(ranks * pos_onehot, axis=-1)            # (T, k)
    keep = within < cap
    gate_vals = gate_vals * keep

    # dispatch (T, E, C) one-hot: token t -> expert e at queue slot c
    slot_onehot = jax.nn.one_hot(within, cap, dtype=xt.dtype)        # (T,k,C)
    disp = jnp.einsum("tke,tkc->tec", pos_onehot.astype(xt.dtype) *
                      keep[..., None].astype(xt.dtype), slot_onehot)
    comb = jnp.einsum("tke,tkc,tk->tec", pos_onehot.astype(jnp.float32),
                      slot_onehot.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(xt.dtype)

    xe = jnp.einsum("tec,td->ecd", disp, xt)                 # (E, C, D)
    xe = shard_activation(xe, None)  # experts already sharded via weights
    h = swiglu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
               jnp.einsum("ecd,edf->ecf", xe, p["w_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb, ye)

    if cfg.n_shared_experts:
        y = y + mlp_forward(p["shared"], xt)
    return y.reshape(b, s, d), aux


def _dispatch_gather(p, cfg: ModelConfig, xt: Array, expert_idx: Array,
                     gate_vals: Array, cap_global: int) -> Array:
    """Shard-local argsort-gather dispatch (§Perf iteration 2).

    Two problems with the GShard one-hot formulation, both measured on
    kimi-k2 train_4k:
      (a) the (T, E, C) dispatch einsums are O(T*E*C*D) FLOPs — 97% of
          the cell's compute (150 s/step of the 170 s total);
      (b) GLOBAL routing makes every dispatch op cross data shards, which
          GSPMD can only lower as partial-scatter + 6.8 TB of all-reduce.

    Fix: tokens are viewed as (n_data_shards, T_local); routing, capacity,
    argsort, scatter and gather are vmapped over the shard axis, so every
    index op stays on-shard (capacity becomes per-shard — the standard
    local-capacity semantics of real EP systems). Compute drops to the
    expert FFN itself; the MoE block adds no collectives beyond the FSDP
    weight gathers.

    Priority semantics within a shard match the dense path exactly:
    slot-major, token order within a slot (on a 1-shard mesh the two
    implementations agree to float tolerance — tested).
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    from .common import data_shard_count

    ns = data_shard_count()
    if t % ns != 0:
        ns = 1
    tl = t // ns
    cap = max(int(cfg.capacity_factor * tl * k / e), 4)

    xs = xt.reshape(ns, tl, d)
    ei = expert_idx.reshape(ns, tl, k)
    gv = gate_vals.reshape(ns, tl, k)

    def one_shard(x_s, ei_s, gv_s):
        # slot-major flattening: row j*tl + t <-> (choice j, token t)
        flat_e = ei_s.T.reshape(-1)                       # (k*tl,)
        flat_tok = jnp.tile(jnp.arange(tl), k)
        flat_gate = gv_s.T.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)          # group by expert
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        rank = jnp.arange(k * tl) - seg_start[sorted_e]
        keep = rank < cap
        slot = jnp.where(keep, sorted_e * cap + rank, e * cap)
        src_tok = flat_tok[order]
        xe_flat = jnp.zeros((e * cap, d), x_s.dtype).at[slot].set(
            x_s[src_tok], mode="drop")
        # inverse map for the combine gather
        slot_of = jnp.zeros((k * tl,), jnp.int32).at[order].set(
            slot.astype(jnp.int32))
        return xe_flat.reshape(e, cap, d), slot_of, flat_gate

    xe, slot_of, flat_gate = jax.vmap(one_shard)(xs, ei, gv)
    xe = shard_activation(xe, "experts4")                 # (ns, E, C, D)

    h = swiglu(jnp.einsum("secd,edf->secf", xe, p["w_gate"]),
               jnp.einsum("secd,edf->secf", xe, p["w_up"]))
    ye = jnp.einsum("secf,efd->secd", h, p["w_down"])
    ye = shard_activation(ye, "experts4")

    def combine(ye_s, slot_of_s, gate_s):
        ye_flat = jnp.concatenate(
            [ye_s.reshape(e * cap, d),
             jnp.zeros((1, d), ye_s.dtype)], axis=0)      # OOB row = 0
        picked = ye_flat[slot_of_s].reshape(k, tl, d)
        return jnp.sum(picked.astype(jnp.float32) *
                       gate_s.reshape(k, tl, 1), axis=0)

    y = jax.vmap(combine)(ye, slot_of, flat_gate)
    return y.reshape(t, d).astype(xt.dtype)

"""Rotary position embeddings: full, half (ChatGLM 2D), and M-RoPE (Qwen2-VL).

All variants share one primitive: rotate pairs (even, odd) of feature
channels by position-dependent angles. They differ in WHICH channels rotate
and WHERE the position indices come from:

  full   — every channel pair, positions = token index (Llama/Qwen/Gemma).
  half   — only the first half of head_dim rotates (ChatGLM's "RoPE 2d" /
           partial rotary); the rest passes through.
  mrope  — channel pairs are split into 3 groups (temporal/height/width)
           rotated by 3 separate position streams (Qwen2-VL M-RoPE). Text
           tokens carry identical t/h/w positions, so mrope == full there.

Inputs may have ANY number of head axes between (B, S, ...) and the
trailing hd axis — the GQA layout passes q as (B,S,Hk,G,hd) and k as
(B,S,Hk,hd); cos/sin broadcast across the middle axes.
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

# M-RoPE channel-group split (fractions of head_dim/2): temporal, height, width
_MROPE_SPLIT = (0.25, 0.375, 0.375)


def _angles(positions: Array, dim: int, theta: float) -> tuple[Array, Array]:
    """cos/sin tables: positions (..., S) -> (..., S, dim//2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _expand(t: Array, ndim: int) -> Array:
    """(B, S, c) -> (B, S, 1...1, c) matching an ndim-rank head tensor."""
    return t.reshape(t.shape[0], t.shape[1], *([1] * (ndim - 3)), t.shape[-1])


def _rotate(x: Array, cos: Array, sin: Array) -> Array:
    """Rotate halves: x (..., dim) with cos/sin broadcastable (..., dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _mrope_tables(positions: Array, hd: int, theta: float):
    cos, sin = _angles(positions, hd, theta)      # (B, 3, S, hd/2)
    half = hd // 2
    sizes = [int(round(f * half)) for f in _MROPE_SPLIT]
    sizes[-1] = half - sizes[0] - sizes[1]
    parts_c, parts_s = [], []
    off = 0
    for g, sz in enumerate(sizes):
        parts_c.append(cos[:, g, :, off:off + sz])
        parts_s.append(sin[:, g, :, off:off + sz])
        off += sz
    return jnp.concatenate(parts_c, axis=-1), jnp.concatenate(parts_s, axis=-1)


def apply_rope(
    q: Array,
    k: Array,
    positions: Array,
    *,
    style: str = "full",
    theta: float = 10000.0,
) -> tuple[Array, Array]:
    """q: (B,S,...,hd); k: (B,S,...,hd); positions (B,S) or (B,3,S)."""
    hd = q.shape[-1]
    dtype = q.dtype

    if style == "mrope":
        if positions.ndim == 2:       # text-only: replicate into 3 streams
            positions = jnp.broadcast_to(
                positions[:, None, :],
                (positions.shape[0], 3, positions.shape[1]))
        cos, sin = _mrope_tables(positions, hd, theta)       # (B,S,hd/2)
        q_out = _rotate(q.astype(jnp.float32), _expand(cos, q.ndim),
                        _expand(sin, q.ndim))
        k_out = _rotate(k.astype(jnp.float32), _expand(cos, k.ndim),
                        _expand(sin, k.ndim))
        return q_out.astype(dtype), k_out.astype(dtype)

    if style == "half":
        rot = hd // 2
        cos, sin = _angles(positions, rot, theta)            # (B,S,rot/2)
        q32, k32 = q.astype(jnp.float32), k.astype(jnp.float32)
        q_out = jnp.concatenate(
            [_rotate(q32[..., :rot], _expand(cos, q.ndim),
                     _expand(sin, q.ndim)), q32[..., rot:]], axis=-1)
        k_out = jnp.concatenate(
            [_rotate(k32[..., :rot], _expand(cos, k.ndim),
                     _expand(sin, k.ndim)), k32[..., rot:]], axis=-1)
        return q_out.astype(dtype), k_out.astype(dtype)

    # full
    cos, sin = _angles(positions, hd, theta)
    q_out = _rotate(q.astype(jnp.float32), _expand(cos, q.ndim),
                    _expand(sin, q.ndim))
    k_out = _rotate(k.astype(jnp.float32), _expand(cos, k.ndim),
                    _expand(sin, k.ndim))
    return q_out.astype(dtype), k_out.astype(dtype)

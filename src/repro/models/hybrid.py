"""Hybrid SSM+attention assemblies: mamba2 (pure SSM) and zamba2 (hybrid).

zamba2-7b: a stack of Mamba2 blocks with ONE weight-tied ("shared") GQA
attention block invoked after every `attn_every` Mamba layers (paper:
arXiv:2411.15242). The shared block's weights appear once in the param
tree; each invocation carries its own KV cache. Layout for n_layers=81,
attn_every=6: 13 groups of (6 mamba + shared attn) + 3 trailing mamba.

mamba2-130m: attn_every=0 -> plain scan over Mamba2 blocks; decode state
is O(1) per layer, which is why long_500k runs trivially for SSM archs.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import KVCache, attn_decode, attn_forward, init_attn, init_cache
from .common import ModelConfig, embed_init, maybe_remat, rms_norm, shard_activation
from .mamba2 import (MambaState, init_mamba, init_mamba_state, mamba_decode,
                     mamba_forward)
from .mlp import init_mlp, mlp_forward
from .transformer import _pack_full_cache, _prepend_axes

Array = jnp.ndarray


def _init_mamba_layer(rng, cfg: ModelConfig):
    p, s = {}, {}
    p["ln"], s["ln"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)
    p["mix"], s["mix"] = init_mamba(rng, cfg)
    return p, s


def _axes_of(init_fn, cfg):
    box = {}

    def f(r):
        params, specs = init_fn(r, cfg)
        box["s"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def _layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(groups, mamba_per_group, remainder)."""
    if cfg.attn_every <= 0:
        return 0, 0, cfg.n_layers
    g = cfg.n_layers // cfg.attn_every
    return g, cfg.attn_every, cfg.n_layers % cfg.attn_every


def init_hybrid(rng, cfg: ModelConfig):
    groups, per, rem = _layout(cfg)
    ks = jax.random.split(rng, 5)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                        cfg.param_dtype)
    w = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
         * 0.02).astype(cfg.param_dtype)
    p["unembed"], s["unembed"] = w, ("embed", "vocab")
    p["ln_f"], s["ln_f"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)

    layer_axes = _axes_of(_init_mamba_layer, cfg)
    n_grouped = groups * per
    if n_grouped:
        rngs = jax.random.split(ks[2], groups)

        def ginit(r):
            return jax.vmap(lambda rr: _init_mamba_layer(rr, cfg)[0])(
                jax.random.split(r, per))

        p["mamba"] = jax.vmap(ginit)(rngs)
        s["mamba"] = _prepend_axes(layer_axes, ("layers", "stack"))
        # ONE shared transformer block (attn + MLP, weight-tied across
        # invocations — zamba2's d_ff lives here)
        p["shared_ln"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
        s["shared_ln"] = ("embed",)
        p["shared_attn"], s["shared_attn"] = init_attn(ks[3], cfg)
        if cfg.d_ff:
            kmlp = jax.random.fold_in(ks[3], 1)
            p["shared_ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
            s["shared_ln2"] = ("embed",)
            p["shared_mlp"], s["shared_mlp"] = init_mlp(kmlp, cfg)
    if rem:
        rngs = jax.random.split(ks[4], rem)
        p["rem"] = jax.vmap(lambda r: _init_mamba_layer(r, cfg)[0])(rngs)
        s["rem"] = _prepend_axes(layer_axes, ("layers",))
    return p, s


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mamba_body(cfg):
    def body(lp, x):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        return shard_activation(x + mamba_forward(lp["mix"], cfg, h),
                                "residual")
    return body


def hybrid_logits(p, cfg: ModelConfig, batch: dict):
    groups, per, rem = _layout(cfg)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    x = shard_activation(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    body = maybe_remat(_mamba_body(cfg), cfg.remat)

    def shared(x_):
        h = rms_norm(x_, p["shared_ln"], cfg.norm_eps)
        x_ = x_ + attn_forward(p["shared_attn"], cfg, h, positions,
                               kind="causal")
        if "shared_mlp" in p:
            h = rms_norm(x_, p["shared_ln2"], cfg.norm_eps)
            x_ = x_ + mlp_forward(p["shared_mlp"], h)
        return x_

    if groups:
        shared_r = maybe_remat(shared, cfg.remat)

        def group(x_, gp):
            def inner(x2, lp):
                return body(lp, x2), None

            x_, _ = jax.lax.scan(inner, x_, gp)
            return shared_r(x_), None

        x, _ = jax.lax.scan(group, x, p["mamba"])
    if rem:
        def f(x_, lp):
            return body(lp, x_), None

        x, _ = jax.lax.scan(f, x, p["rem"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = (x @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return shard_activation(logits, "logits"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


class HybridCache(NamedTuple):
    mamba: Any          # MambaState stacked (G, per, ...) or None
    attn: Any           # KVCache stacked (G, ...) or None
    rem: Any            # MambaState stacked (rem, ...) or None


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> HybridCache:
    groups, per, rem = _layout(cfg)

    def mstack(prefix):
        one = init_mamba_state(cfg, batch)
        return MambaState(
            conv=jnp.zeros(prefix + one.conv.shape, one.conv.dtype),
            ssm=jnp.zeros(prefix + one.ssm.shape, one.ssm.dtype),
        )

    mam = attn = remc = None
    if groups:
        mam = mstack((groups, per))
        one = init_cache(cfg, batch, max_len)
        attn = KVCache(
            k=jnp.zeros((groups,) + one.k.shape, one.k.dtype),
            v=jnp.zeros((groups,) + one.v.shape, one.v.dtype),
            pos=jnp.full((groups,) + one.pos.shape, -1, jnp.int32),
        )
    if rem:
        remc = mstack((rem,))
    return HybridCache(mamba=mam, attn=attn, rem=remc)


def _mamba_forward_with_state(lp, cfg: ModelConfig, x: Array):
    """Full-seq mamba + exact final MambaState (chunk-scan carry, no extra
    pass — see mamba2.mamba_forward(return_state=True))."""
    return mamba_forward(lp["mix"], cfg, x, return_state=True)


def hybrid_prefill(p, cfg: ModelConfig, batch: dict, max_len: int):
    groups, per, rem = _layout(cfg)
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    pos_row = positions[0]

    def mbody(lp, x_):
        h = rms_norm(x_, lp["ln"], cfg.norm_eps)
        y, st = _mamba_forward_with_state(lp, cfg, h)
        return x_ + y, st

    mam_c = attn_c = rem_c = None
    if groups:
        def group(x_, gp):
            def inner(x2, lp):
                x2, st = mbody(lp, x2)
                return x2, st

            x_, sts = jax.lax.scan(inner, x_, gp)
            h = rms_norm(x_, p["shared_ln"], cfg.norm_eps)
            attn_out, (k, v) = attn_forward(p["shared_attn"], cfg, h,
                                            positions, kind="causal",
                                            return_kv=True)
            x_ = x_ + attn_out
            if "shared_mlp" in p:
                h = rms_norm(x_, p["shared_ln2"], cfg.norm_eps)
                x_ = x_ + mlp_forward(p["shared_mlp"], h)
            return x_, (sts, k, v)

        x, (mam_c, ks_, vs_) = jax.lax.scan(group, x, p["mamba"])
        attn_c = jax.vmap(lambda k_, v_: _pack_full_cache(k_, v_, pos_row,
                                                          max_len))(ks_, vs_)
    if rem:
        def f(x_, lp):
            x_, st = mbody(lp, x_)
            return x_, st

        x, rem_c = jax.lax.scan(f, x, p["rem"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, HybridCache(mamba=mam_c, attn=attn_c, rem=rem_c)


def hybrid_decode(p, cfg: ModelConfig, cache: HybridCache, tokens: Array,
                  pos: Array):
    groups, per, rem = _layout(cfg)
    x = jnp.take(p["embed"], tokens[:, None], axis=0).astype(cfg.compute_dtype)

    def mdec(lp, x_, st):
        h = rms_norm(x_, lp["ln"], cfg.norm_eps)
        y, st_new = mamba_decode(lp["mix"], cfg, h, st)
        return x_ + y, st_new

    new_mam = new_attn = new_rem = None
    if groups:
        def group(x_, gc):
            gp, gst, c_attn = gc

            def inner(x2, lc):
                lp, st = lc
                x2, st_new = mdec(lp, x2, st)
                return x2, st_new

            x_, st_new = jax.lax.scan(inner, x_, (gp, gst))
            h = rms_norm(x_, p["shared_ln"], cfg.norm_eps)
            attn_out, c_new = attn_decode(p["shared_attn"], cfg, h, pos,
                                          c_attn)
            x_ = x_ + attn_out
            if "shared_mlp" in p:
                h = rms_norm(x_, p["shared_ln2"], cfg.norm_eps)
                x_ = x_ + mlp_forward(p["shared_mlp"], h)
            return x_, (st_new, c_new)

        x, (new_mam, new_attn) = jax.lax.scan(
            group, x, (p["mamba"], cache.mamba, cache.attn))
    if rem:
        def f(x_, lc):
            lp, st = lc
            x_, st_new = mdec(lp, x_, st)
            return x_, st_new

        x, new_rem = jax.lax.scan(f, x, (p["rem"], cache.rem))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, HybridCache(mamba=new_mam, attn=new_attn, rem=new_rem)

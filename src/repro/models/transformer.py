"""Decoder-only LM assembly (dense / MoE / sliding-window-interleave / VLM).

Structure notes:
  * Per-layer params are stacked and consumed with lax.scan -> HLO size is
    depth-independent; remat is applied per layer body.
  * Sliding-window archs (gemma3, 5 local : 1 global) use a GROUPED scan:
    the layer stack splits into `full_groups` groups of (`global_every`-1
    local + 1 global) layers plus a local-only remainder stack. Local
    layers carry ring-buffer caches of size `window`; global layers carry
    full-length caches — this is what makes long_500k decode genuinely
    sub-quadratic in memory AND keeps 5/6 of prefill attention O(S*W).
  * VLM (qwen2-vl): patch embeddings from the (stubbed) vision frontend
    replace the first n_patches token embeddings; M-RoPE positions
    (B, 3, S) come in through the batch.

Batch dict keys: tokens (B,S) int32; positions (B,S) or (B,3,S);
optional patch_embeds (B, n_patches, D).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attn_decode, attn_forward, init_attn,
                        init_cache)
from .common import (ModelConfig, embed_init, maybe_remat, rms_norm,
                     shard_activation)
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------


def _init_layer(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    p, s = {}, {}
    p["ln1"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    s["ln1"] = ("embed",)
    p["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    s["ln2"] = ("embed",)
    p["attn"], s["attn"] = init_attn(ks[0], cfg)
    if cfg.n_experts:
        p["ff"], s["ff"] = init_moe(ks[1], cfg)
    else:
        p["ff"], s["ff"] = init_mlp(ks[1], cfg)
    return p, s


def _layer_fwd(cfg: ModelConfig, lp, x: Array, positions: Array, *,
               kind: str, window: int):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + attn_forward(lp["attn"], cfg, h, positions, kind=kind,
                         window=window)
    x = shard_activation(x, "residual")
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, aux = moe_forward(lp["ff"], cfg, h)
    else:
        ff, aux = mlp_forward(lp["ff"], h), jnp.zeros((), jnp.float32)
    x = shard_activation(x + ff, "residual")
    return x, aux


def _layer_prefill(cfg: ModelConfig, lp, x: Array, positions: Array, *,
                   kind: str, window: int):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, (k, v) = attn_forward(lp["attn"], cfg, h, positions, kind=kind,
                                    window=window, return_kv=True)
    x = x + attn_out
    x = shard_activation(x, "residual")
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, _ = moe_forward(lp["ff"], cfg, h)
    else:
        ff = mlp_forward(lp["ff"], h)
    x = shard_activation(x + ff, "residual")
    return x, (k, v)


def _layer_decode(cfg: ModelConfig, lp, x1: Array, pos: Array,
                  cache: KVCache, *, window: int):
    h = rms_norm(x1, lp["ln1"], cfg.norm_eps)
    attn_out, cache = attn_decode(lp["attn"], cfg, h, pos, cache,
                                  window=window)
    x1 = x1 + attn_out
    h = rms_norm(x1, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, _ = moe_forward(lp["ff"], cfg, h)
    else:
        ff = mlp_forward(lp["ff"], h)
    return x1 + ff, cache


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackLayout:
    """How n_layers splits into scanned stacks."""

    full_groups: int      # groups of (locals_per_group local + 1 global)
    locals_per_group: int
    remainder: int        # trailing local-only layers

    @classmethod
    def of(cls, cfg: ModelConfig) -> "StackLayout":
        if cfg.window <= 0:
            return cls(full_groups=0, locals_per_group=0,
                       remainder=cfg.n_layers)
        g = cfg.global_every
        return cls(full_groups=cfg.n_layers // g, locals_per_group=g - 1,
                   remainder=cfg.n_layers % g)


def is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def _prepend_axes(tree, prefix: tuple):
    return jax.tree_util.tree_map(lambda ax: prefix + ax, tree,
                                  is_leaf=is_axes_leaf)


def _layer_axes(cfg: ModelConfig):
    """Axes tree of one layer WITHOUT materializing params (eval_shape +
    static side-channel; matters at 16B params/layer)."""
    box = {}

    def f(r):
        params, specs = _init_layer(r, cfg)
        box["s"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def init_lm(rng, cfg: ModelConfig):
    """Returns (params, logical-axes tree)."""
    lay = StackLayout.of(cfg)
    ks = jax.random.split(rng, 6)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                        cfg.param_dtype)
    if not cfg.tie_embeddings:
        w = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size),
                               jnp.float32) * 0.02).astype(cfg.param_dtype)
        p["unembed"], s["unembed"] = w, ("embed", "vocab")
    p["ln_f"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    s["ln_f"] = ("embed",)

    layer_axes = _layer_axes(cfg)
    if lay.full_groups:
        # local stack (G, locals_per_group, ...) and global stack (G, ...)
        def group_init(r):
            rl = jax.random.split(r, lay.locals_per_group)
            pl = jax.vmap(lambda rr: _init_layer(rr, cfg)[0])(rl)
            pg = _init_layer(jax.random.fold_in(r, 7), cfg)[0]
            return pl, pg

        rngs = jax.random.split(ks[2], lay.full_groups)
        p["local"], p["global"] = jax.vmap(group_init)(rngs)
        s["local"] = _prepend_axes(layer_axes, ("layers", "stack"))
        s["global"] = _prepend_axes(layer_axes, ("layers",))
    if lay.remainder:
        rngs = jax.random.split(ks[3], lay.remainder)
        p["rem"] = jax.vmap(lambda r: _init_layer(r, cfg)[0])(rngs)
        s["rem"] = _prepend_axes(layer_axes, ("layers",))
    return p, s


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(p, cfg: ModelConfig, batch: dict) -> Array:
    tokens = batch["tokens"]
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    if cfg.n_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, pe.shape[1]:, :]], axis=1)
    return shard_activation(x, "residual")


def _head(p, cfg: ModelConfig, x: Array) -> Array:
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return shard_activation(logits, "logits")


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def _positions_of(batch: dict) -> Array:
    if "positions" in batch:
        return batch["positions"]
    t = batch["tokens"]
    return jnp.broadcast_to(jnp.arange(t.shape[1]), t.shape)


def lm_logits(p, cfg: ModelConfig, batch: dict):
    """Full-sequence forward: (logits (B,S,V) f32, aux loss)."""
    lay = StackLayout.of(cfg)
    x = _embed_tokens(p, cfg, batch)
    positions = _positions_of(batch)
    aux0 = jnp.zeros((), jnp.float32)

    local_body = maybe_remat(
        lambda lp, x_: _layer_fwd(cfg, lp, x_, positions, kind="window",
                                  window=cfg.window), cfg.remat)
    global_body = maybe_remat(
        lambda lp, x_: _layer_fwd(cfg, lp, x_, positions, kind="causal",
                                  window=0), cfg.remat)
    plain_body = maybe_remat(
        lambda lp, x_: _layer_fwd(cfg, lp, x_, positions, kind="causal",
                                  window=0), cfg.remat)

    if lay.full_groups:
        def group(carry, gp):
            x_, aux = carry
            pl, pg = gp

            def inner(c2, lp):
                x2, a2 = c2
                x2, a = local_body(lp, x2)
                return (x2, a2 + a), None

            (x_, aux), _ = jax.lax.scan(inner, (x_, aux), pl)
            x_, a = global_body(pg, x_)
            return (x_, aux + a), None

        (x, aux0), _ = jax.lax.scan(group, (x, aux0),
                                    (p["local"], p["global"]))
        rem_body = local_body                      # remainder layers are local
    else:
        rem_body = plain_body
    if lay.remainder:
        def f(carry, lp):
            x_, aux = carry
            x_, a = rem_body(lp, x_)
            return (x_, aux + a), None

        (x, aux0), _ = jax.lax.scan(f, (x, aux0), p["rem"])
    return _head(p, cfg, x), aux0


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


class LMCache(NamedTuple):
    local: Any      # KVCache stacked (G, locals_per_group, ...) or None
    global_: Any    # KVCache stacked (G, ...) or None
    rem: Any        # KVCache stacked (rem, ...) or None


def lm_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> LMCache:
    lay = StackLayout.of(cfg)

    def stack(prefix: tuple, window: int):
        one = init_cache(cfg, batch, max_len, window=window)
        return KVCache(
            k=jnp.zeros(prefix + one.k.shape, one.k.dtype),
            v=jnp.zeros(prefix + one.v.shape, one.v.dtype),
            pos=jnp.full(prefix + one.pos.shape, -1, jnp.int32),
        )

    local = glob = rem = None
    if lay.full_groups:
        local = stack((lay.full_groups, lay.locals_per_group), cfg.window)
        glob = stack((lay.full_groups,), 0)
    if lay.remainder:
        rem = stack((lay.remainder,), cfg.window if lay.full_groups else 0)
    return LMCache(local=local, global_=glob, rem=rem)


def _pack_window_cache(k: Array, v: Array, positions: Array, size: int) -> KVCache:
    """Build a ring cache from full-seq K/V (keep last `size` positions)."""
    b, s = k.shape[0], k.shape[1]
    if s >= size:
        k_last, v_last = k[:, s - size:], v[:, s - size:]
        pos_last = positions[s - size:]
        shift = s % size
        return KVCache(
            k=jnp.roll(k_last, shift, axis=1),
            v=jnp.roll(v_last, shift, axis=1),
            pos=jnp.broadcast_to(jnp.roll(pos_last, shift)[None],
                                 (b, size)).astype(jnp.int32),
        )
    pad = size - s
    return KVCache(
        k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        pos=jnp.pad(jnp.broadcast_to(positions[None], (b, s)).astype(jnp.int32),
                    ((0, 0), (0, pad)), constant_values=-1),
    )


def _pack_full_cache(k: Array, v: Array, positions: Array, size: int) -> KVCache:
    b, s = k.shape[0], k.shape[1]
    pad = size - s
    return KVCache(
        k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        pos=jnp.pad(jnp.broadcast_to(positions[None], (b, s)).astype(jnp.int32),
                    ((0, 0), (0, pad)), constant_values=-1),
    )


def lm_prefill(p, cfg: ModelConfig, batch: dict, max_len: int):
    """Prefill: returns (last-position logits (B, V), LMCache)."""
    lay = StackLayout.of(cfg)
    x = _embed_tokens(p, cfg, batch)
    positions = _positions_of(batch)
    pos1d = positions[:, 0] if positions.ndim == 3 else positions
    pos_row = pos1d[0]
    s = x.shape[1]

    def local_pre(lp, x_):
        return _layer_prefill(cfg, lp, x_, positions, kind="window",
                              window=cfg.window)

    def global_pre(lp, x_):
        return _layer_prefill(cfg, lp, x_, positions, kind="causal", window=0)

    local_c = glob_c = rem_c = None
    if lay.full_groups:
        def group(x_, gp):
            pl, pg = gp

            def inner(x2, lp):
                x2, kv = local_pre(lp, x2)
                return x2, kv

            x_, kv_l = jax.lax.scan(inner, x_, pl)
            x_, kv_g = global_pre(pg, x_)
            return x_, (kv_l, kv_g)

        x, (kv_l, kv_g) = jax.lax.scan(group, x, (p["local"], p["global"]))
        # kv_l: (G, 5, B, S, Hk, hd); kv_g: (G, B, S, Hk, hd)
        local_c = jax.vmap(jax.vmap(
            lambda k_, v_: _pack_window_cache(k_, v_, pos_row, cfg.window)))(
                kv_l[0], kv_l[1])
        glob_c = jax.vmap(
            lambda k_, v_: _pack_full_cache(k_, v_, pos_row, max_len))(
                kv_g[0], kv_g[1])
        rem_kind = local_pre
        rem_window = cfg.window
    else:
        rem_kind = global_pre
        rem_window = 0
    if lay.remainder:
        def f(x_, lp):
            x_, kv = rem_kind(lp, x_)
            return x_, kv

        x, kv_r = jax.lax.scan(f, x, p["rem"])
        if rem_window:
            rem_c = jax.vmap(
                lambda k_, v_: _pack_window_cache(k_, v_, pos_row, rem_window))(
                    kv_r[0], kv_r[1])
        else:
            rem_c = jax.vmap(
                lambda k_, v_: _pack_full_cache(k_, v_, pos_row, max_len))(
                    kv_r[0], kv_r[1])

    logits_last = _head(p, cfg, x[:, -1:, :])[:, 0]
    return logits_last, LMCache(local=local_c, global_=glob_c, rem=rem_c)


def lm_decode(p, cfg: ModelConfig, cache: LMCache, tokens: Array, pos: Array):
    """One-token decode. tokens: (B,) int32; pos: (B,) absolute positions.

    Returns (logits (B, V), new LMCache).
    """
    lay = StackLayout.of(cfg)
    batch = {"tokens": tokens[:, None]}
    x = _embed_tokens(p, cfg, batch)

    def local_dec(lp, x_, c):
        return _layer_decode(cfg, lp, x_, pos, c, window=cfg.window)

    def global_dec(lp, x_, c):
        return _layer_decode(cfg, lp, x_, pos, c, window=0)

    new_local = new_glob = new_rem = None
    if lay.full_groups:
        def group(x_, gp):
            pl, pg, cl, cg = gp

            def inner(x2, lc):
                lp_, c_ = lc
                x2, c_new = local_dec(lp_, x2, c_)
                return x2, c_new

            x_, cl_new = jax.lax.scan(inner, x_, (pl, cl))
            x_, cg_new = global_dec(pg, x_, cg)
            return x_, (cl_new, cg_new)

        x, (new_local, new_glob) = jax.lax.scan(
            group, x, (p["local"], p["global"], cache.local, cache.global_))
        rem_dec = local_dec
    else:
        rem_dec = global_dec
    if lay.remainder:
        def f(x_, lc):
            lp_, c_ = lc
            x_, c_new = rem_dec(lp_, x_, c_)
            return x_, c_new

        x, new_rem = jax.lax.scan(f, x, (p["rem"], cache.rem))

    logits = _head(p, cfg, x)[:, 0]
    return logits, LMCache(local=new_local, global_=new_glob, rem=new_rem)

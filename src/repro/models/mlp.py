"""Gated MLP (SwiGLU) — the dense FFN used by every assigned transformer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, shard_activation, swiglu

Array = jnp.ndarray


def init_mlp(rng, cfg: ModelConfig, *, d_model: int | None = None,
             d_ff: int | None = None, axes=("embed", "mlp")):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 3)
    p, s = {}, {}
    p["gate"], s["gate"] = dense_init(ks[0], d, f, dt, axes)
    p["up"], s["up"] = dense_init(ks[1], d, f, dt, axes)
    p["down"], s["down"] = dense_init(ks[2], f, d, dt, axes[::-1])
    return p, s


def mlp_forward(p, x: Array) -> Array:
    h = swiglu(x @ p["gate"], x @ p["up"])
    h = shard_activation(h, "ffh")
    return h @ p["down"]

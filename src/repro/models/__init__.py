"""Model zoo: the 10 assigned architectures behind one functional API."""
from .common import (LOGICAL_RULES, ModelConfig, batch_axes_of,
                     logical_to_mesh, param_partition_specs, rms_norm,
                     set_activation_rules, shard_activation)
from .registry import (SHAPES, Model, ShapeSpec, batch_specs, build_model,
                       decode_specs, make_concrete_batch, shape_applicable)

__all__ = [
    "LOGICAL_RULES", "ModelConfig", "batch_axes_of", "logical_to_mesh",
    "param_partition_specs", "rms_norm", "set_activation_rules",
    "shard_activation", "SHAPES", "Model", "ShapeSpec", "batch_specs",
    "build_model", "decode_specs", "make_concrete_batch", "shape_applicable",
]

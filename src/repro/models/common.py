"""Shared model substrate: config, init helpers, norms, sharding hooks.

Design notes (DESIGN.md sec. 5/6):
  * Parameters are nested dicts; per-layer params are STACKED on a leading
    `layers` axis and consumed with lax.scan so HLO size is depth-independent.
  * Every parameter carries a logical-axis annotation (via the parallel
    `specs` tree built by the init functions); `logical_to_mesh` maps
    logical axes to mesh axes (TP over 'model', FSDP over 'data'(+'pod'),
    EP over 'model').
  * Activation sharding is enforced with `shard_activation` hooks
    (batch -> data axes, optional sequence -> 'model' between layers =
    Megatron-style sequence parallelism), so that GSPMD has no freedom to
    replicate the residual stream at large scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config to describe every assigned architecture (see configs/)."""

    arch: str = "custom"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0                # 0 => d_model // n_heads
    d_ff: int = 256
    vocab_size: int = 256
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rope_style: str = "full"         # full | half (chatglm 2d) | mrope (qwen2-vl)
    # -- sliding-window interleave (gemma3): every `global_every`-th layer is
    #    global, others use `window`; 0 disables (all global) --
    window: int = 0
    global_every: int = 6
    # -- MoE --
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "gather"     # 'gather' (argsort dispatch, §Perf) |
    #                              'dense' (GShard one-hot einsum baseline)
    # -- SSM (mamba2 / zamba2) --
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_every: int = 0              # zamba2: shared attn block every k layers
    # -- enc-dec (seamless) --
    n_enc_layers: int = 0
    # -- vlm --
    n_patches: int = 0               # patch embeddings scattered into prefix
    # -- norm / numerics --
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    # -- parallelism --
    remat: bool = True
    seq_shard_activations: bool = True
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window interleave."""
        return self.family in ("ssm", "hybrid") or self.window > 0


# ---------------------------------------------------------------------------
# Logical axis -> mesh axis rules
# ---------------------------------------------------------------------------

# Default rules: TP over 'model', FSDP over 'data' (+'pod' folded into data
# sharding only for the optimizer/flat vectors; weights use 'data' alone so
# inter-pod traffic stays gradient-only).
LOGICAL_RULES: dict[str, Any] = {
    "vocab": "model",
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "stack": None,
    "conv": None,
    "state": None,
    "ssm_heads": "model",
    None: None,
}


def logical_to_mesh(axes: Sequence[Optional[str]]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    return P(*(LOGICAL_RULES.get(a, None) for a in axes))


class SpecTree(dict):
    """Parallel dict tree holding logical-axis tuples for each param."""


def param_partition_specs(specs: Any) -> Any:
    """Convert a logical-axes tree (same structure as params) to PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: logical_to_mesh(axes),
        specs,
        is_leaf=lambda x: isinstance(x, tuple),
    )


# ---------------------------------------------------------------------------
# Initializers — every init returns (param, logical_axes)
# ---------------------------------------------------------------------------


def dense_init(rng, in_dim: int, out_dim: int, dtype, axes=("embed", "mlp"),
               scale: float | None = None):
    s = scale if scale is not None else 1.0 / jnp.sqrt(in_dim)
    w = (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * s).astype(dtype)
    return w, axes


def embed_init(rng, vocab: int, d_model: int, dtype):
    w = (jax.random.normal(rng, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)
    return w, ("vocab", "embed")


def zeros_init(shape, dtype, axes):
    return jnp.zeros(shape, dtype), axes


def ones_init(shape, dtype, axes):
    return jnp.ones(shape, dtype), axes


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Activation sharding hooks
# ---------------------------------------------------------------------------


def batch_axes_of(mesh) -> tuple[str, ...]:
    names = tuple(mesh.axis_names)
    return ("pod", "data") if "pod" in names else ("data",)


_ACTIVATION_RULES: dict[str, P] = {}
_DATA_SHARDS: list[int] = [1]


def data_shard_count() -> int:
    """Number of batch shards the current mesh provides (1 on CPU tests).

    MoE routing is SHARD-LOCAL (per-data-shard capacity): the dispatch
    indices then never cross shards, which is what keeps the gather path
    collective-free (EXPERIMENTS.md §Perf iteration 2b)."""
    return _DATA_SHARDS[0]


def set_activation_rules(mesh, seq_shard: bool) -> None:
    """Install global activation-sharding rules for the current mesh.

    Called once by the step builders (train/serve) before tracing; layers
    call ``shard_activation(x, kind)``. Keeping this a module-global avoids
    threading mesh context through every layer signature.
    """
    b = batch_axes_of(mesh)
    seq = "model" if seq_shard else None
    import numpy as _np

    _DATA_SHARDS[0] = int(_np.prod([mesh.shape[a] for a in b]))
    _ACTIVATION_RULES.clear()
    _ACTIVATION_RULES.update({
        # Megatron-style sequence parallelism: the residual stream between
        # layers is sharded on (batch, seq); inside attention/MLP the
        # activations are resharded to (batch, heads/hidden) — GSPMD turns
        # the transitions into all-gather / reduce-scatter pairs.
        "residual": P(b, seq, None),          # (B, S, D) between layers
        # GQA 5-D layouts (B, S, Hk, G, hd): shard whichever axis the
        # config's attention chose (attention.gqa_shard_axis)
        "q5_hk": P(b, None, "model", None, None),
        "q5_g": P(b, None, None, "model", None),
        "q5_hk_stats": P(b, "model", None, None),   # (B, Hk, G, Sq)
        "q5_g_stats": P(b, None, "model", None),
        "kv4": P(b, None, "model", None),     # (B, S, Hk, hd)
        "experts3": P("model", None, None),   # (E, C, D) MoE dispatch
        "experts4": P(b, "model", None, None),  # (shards, E, C, D)
        "ffh": P(b, None, "model"),           # (B, S, d_ff) inside MLP
        "logits": P(b, None, "model"),        # (B, S, V) vocab-sharded
        "batch_only": P(b),
    })


def shard_activation(x: Array, kind: str) -> Array:
    spec = _ACTIVATION_RULES.get(kind)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # outside jit / no mesh context (CPU smoke tests): no-op
        return x


def maybe_remat(fn, enabled: bool):
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

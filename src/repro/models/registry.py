"""Unified Model API over all architecture families + per-shape input specs.

Model exposes pure functions (params are explicit pytrees):
  init(rng) -> params                      logits(params, batch) -> (lg, aux)
  abstract() -> (param SDS tree, axes)     prefill(params, batch, max_len)
  init_cache(batch, max_len)               decode(params, cache, tokens, pos)

`input_specs(cfg, shape)` builds ShapeDtypeStruct stand-ins for every input
of the step that the shape exercises (train_4k -> train_step;
prefill_32k -> prefill; decode_32k / long_500k -> decode with a filled
cache) — the dry-run contract: shardable, weak-type-correct, no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from . import encdec, hybrid, transformer

Array = jnp.ndarray


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[Any], Any]
    abstract: Callable[[], tuple[Any, Any]]
    logits: Callable[..., tuple[Array, Array]]
    prefill: Callable[..., tuple[Array, Any]]
    decode: Callable[..., tuple[Array, Any]]
    init_cache: Callable[..., Any]


def _abstract_of(init_fn):
    def fn():
        box = {}

        def f(r):
            p, s = init_fn(r)
            box["s"] = s
            return p

        pa = jax.eval_shape(f, jax.random.PRNGKey(0))
        return pa, box["s"]

    return fn


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        init = lambda rng: transformer.init_lm(rng, cfg)
        return Model(
            cfg=cfg,
            init=lambda rng: init(rng)[0],
            abstract=_abstract_of(init),
            logits=lambda p, b: transformer.lm_logits(p, cfg, b),
            prefill=lambda p, b, ml: transformer.lm_prefill(p, cfg, b, ml),
            decode=lambda p, c, t, pos: transformer.lm_decode(p, cfg, c, t, pos),
            init_cache=lambda b, ml: transformer.lm_init_cache(cfg, b, ml),
        )
    if fam in ("ssm", "hybrid"):
        init = lambda rng: hybrid.init_hybrid(rng, cfg)
        return Model(
            cfg=cfg,
            init=lambda rng: init(rng)[0],
            abstract=_abstract_of(init),
            logits=lambda p, b: hybrid.hybrid_logits(p, cfg, b),
            prefill=lambda p, b, ml: hybrid.hybrid_prefill(p, cfg, b, ml),
            decode=lambda p, c, t, pos: hybrid.hybrid_decode(p, cfg, c, t, pos),
            init_cache=lambda b, ml: hybrid.hybrid_init_cache(cfg, b, ml),
        )
    if fam == "encdec":
        init = lambda rng: encdec.init_encdec(rng, cfg)
        return Model(
            cfg=cfg,
            init=lambda rng: init(rng)[0],
            abstract=_abstract_of(init),
            logits=lambda p, b: encdec.encdec_logits(p, cfg, b),
            prefill=lambda p, b, ml: encdec.encdec_prefill(p, cfg, b, ml),
            decode=lambda p, c, t, pos: encdec.encdec_decode(p, cfg, c, t, pos),
            init_cache=lambda b, ml, src_len=None: encdec.encdec_init_cache(
                cfg, b, ml, src_len if src_len is not None else ml),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
    # reduced shapes for CPU smoke tests
    "smoke_train": ShapeSpec("smoke_train", 64, 2, "train"),
    "smoke_prefill": ShapeSpec("smoke_prefill", 32, 2, "prefill"),
    "smoke_decode": ShapeSpec("smoke_decode", 32, 2, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    ss = SHAPES[shape]
    if ss.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic attention (skip per spec)")
    return True, ""


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def batch_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the train/prefill *batch* dict."""
    ss = SHAPES[shape]
    b, s = ss.global_batch, ss.seq_len
    specs: dict[str, Any] = {"tokens": _i32((b, s))}
    if cfg.family == "encdec":
        specs["src_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   cfg.compute_dtype)
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), cfg.compute_dtype)
        specs["positions"] = _i32((b, 3, s))
    return specs


def decode_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the decode step: cache + one token + pos."""
    ss = SHAPES[shape]
    b, s = ss.global_batch, ss.seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"cache": cache, "tokens": _i32((b,)), "pos": _i32((b,))}


def make_concrete_batch(cfg: ModelConfig, shape: str, seed: int = 0) -> dict:
    """Real (random) batch for smoke tests / examples."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, sds in batch_specs(cfg, shape).items():
        key, k = jax.random.split(key)
        if name == "positions":
            # M-RoPE position streams: sequential (text-like), identical
            # across t/h/w so serving (which tracks a scalar position)
            # agrees with the full forward
            s = sds.shape[-1]
            out[name] = jnp.broadcast_to(jnp.arange(s, dtype=sds.dtype),
                                         sds.shape)
        elif jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jax.random.randint(k, sds.shape, 0,
                                           min(cfg.vocab_size, 1000),
                                           dtype=sds.dtype)
        else:
            out[name] = jax.random.normal(k, sds.shape, jnp.float32).astype(
                sds.dtype)
    return out

"""Encoder-decoder assembly (seamless-m4t-large-v2 backbone).

The modality frontend is a STUB per the assignment spec: `input_specs()`
provides precomputed frame embeddings (B, S_src, D) — the speech encoder's
conformer stack is represented by a plain bidirectional transformer over
those frames. The text decoder is causal self-attention + cross-attention
to the encoder output.

Serving: "prefill" = encode source + prefill decoder prompt (builds both
the self-attention KV cache and the fixed cross-attention K/V); "decode" =
one target token against both caches. Cross K/V never changes after
prefill — exactly the cheap half of enc-dec serving.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attn_decode, attn_forward,
                        cross_attn_forward, encode_kv, init_attn, init_cache)
from .common import ModelConfig, embed_init, maybe_remat, rms_norm, shard_activation
from .mlp import init_mlp, mlp_forward
from .transformer import _pack_full_cache, _prepend_axes, is_axes_leaf

Array = jnp.ndarray


def _init_enc_layer(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)
    p["ln2"], s["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)
    p["attn"], s["attn"] = init_attn(ks[0], cfg)
    p["ff"], s["ff"] = init_mlp(ks[1], cfg)
    return p, s


def _init_dec_layer(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    p, s = {}, {}
    for nm in ("ln1", "ln2", "ln3"):
        p[nm], s[nm] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)
    p["attn"], s["attn"] = init_attn(ks[0], cfg)
    p["xattn"], s["xattn"] = init_attn(ks[1], cfg)
    p["ff"], s["ff"] = init_mlp(ks[2], cfg)
    return p, s


def _axes_of(init_fn, cfg):
    box = {}

    def f(r):
        params, specs = init_fn(r, cfg)
        box["s"] = specs
        return params

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["s"]


def init_encdec(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 4)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                        cfg.param_dtype)
    w = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
         * 0.02).astype(cfg.param_dtype)
    p["unembed"], s["unembed"] = w, ("embed", "vocab")
    p["ln_enc"], s["ln_enc"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)
    p["ln_dec"], s["ln_dec"] = jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",)

    n_enc = cfg.n_enc_layers or cfg.n_layers
    enc_rngs = jax.random.split(ks[2], n_enc)
    p["enc"] = jax.vmap(lambda r: _init_enc_layer(r, cfg)[0])(enc_rngs)
    s["enc"] = _prepend_axes(_axes_of(_init_enc_layer, cfg), ("layers",))
    dec_rngs = jax.random.split(ks[3], cfg.n_layers)
    p["dec"] = jax.vmap(lambda r: _init_dec_layer(r, cfg)[0])(dec_rngs)
    s["dec"] = _prepend_axes(_axes_of(_init_dec_layer, cfg), ("layers",))
    return p, s


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def _encode(p, cfg: ModelConfig, src: Array) -> Array:
    """src: (B, S_src, D) precomputed frame embeddings -> encoder output."""
    x = shard_activation(src.astype(cfg.compute_dtype), "residual")
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(lp, x_):
        h = rms_norm(x_, lp["ln1"], cfg.norm_eps)
        x_ = x_ + attn_forward(lp["attn"], cfg, h, positions, kind="full")
        h = rms_norm(x_, lp["ln2"], cfg.norm_eps)
        x_ = shard_activation(x_ + mlp_forward(lp["ff"], h), "residual")
        return x_

    body = maybe_remat(body, cfg.remat)

    def f(x_, lp):
        return body(lp, x_), None

    x, _ = jax.lax.scan(f, x, p["enc"])
    return rms_norm(x, p["ln_enc"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_layer_fwd(cfg, lp, x, positions, enc_kv):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + attn_forward(lp["attn"], cfg, h, positions, kind="causal")
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + cross_attn_forward(lp["xattn"], cfg, h, enc_kv[0], enc_kv[1])
    h = rms_norm(x, lp["ln3"], cfg.norm_eps)
    x = shard_activation(x + mlp_forward(lp["ff"], h), "residual")
    return x


def encdec_logits(p, cfg: ModelConfig, batch: dict):
    """batch: src_frames (B,S_src,D), tokens (B,S_tgt). Returns (logits, 0)."""
    enc_out = _encode(p, cfg, batch["src_frames"])
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    x = shard_activation(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(lp, x_):
        kv = encode_kv(lp["xattn"], cfg, enc_out)
        return _dec_layer_fwd(cfg, lp, x_, positions, kv)

    body = maybe_remat(body, cfg.remat)

    def f(x_, lp):
        return body(lp, x_), None

    x, _ = jax.lax.scan(f, x, p["dec"])
    x = rms_norm(x, p["ln_dec"], cfg.norm_eps)
    logits = (x @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return shard_activation(logits, "logits"), jnp.zeros((), jnp.float32)


class EncDecCache(NamedTuple):
    self_kv: KVCache     # stacked (L, ...)
    cross_k: Array       # (L, B, S_src, Hk, hd)
    cross_v: Array


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                      src_len: int) -> EncDecCache:
    one = init_cache(cfg, batch, max_len)
    L = cfg.n_layers
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return EncDecCache(
        self_kv=KVCache(
            k=jnp.zeros((L,) + one.k.shape, one.k.dtype),
            v=jnp.zeros((L,) + one.v.shape, one.v.dtype),
            pos=jnp.full((L,) + one.pos.shape, -1, jnp.int32),
        ),
        cross_k=jnp.zeros((L, batch, src_len, hk, hd), cfg.compute_dtype),
        cross_v=jnp.zeros((L, batch, src_len, hk, hd), cfg.compute_dtype),
    )


def encdec_prefill(p, cfg: ModelConfig, batch: dict, max_len: int):
    """Encode src + prefill target prompt. Returns (last logits, cache)."""
    enc_out = _encode(p, cfg, batch["src_frames"])
    x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    pos_row = positions[0]

    def f(x_, lp):
        kv = encode_kv(lp["xattn"], cfg, enc_out)
        h = rms_norm(x_, lp["ln1"], cfg.norm_eps)
        attn_out, (k, v) = attn_forward(lp["attn"], cfg, h, positions,
                                        kind="causal", return_kv=True)
        x_ = x_ + attn_out
        h = rms_norm(x_, lp["ln2"], cfg.norm_eps)
        x_ = x_ + cross_attn_forward(lp["xattn"], cfg, h, kv[0], kv[1])
        h = rms_norm(x_, lp["ln3"], cfg.norm_eps)
        x_ = x_ + mlp_forward(lp["ff"], h)
        return x_, (k, v, kv[0], kv[1])

    x, (ks_, vs_, ck, cv) = jax.lax.scan(f, x, p["dec"])
    self_kv = jax.vmap(lambda k_, v_: _pack_full_cache(k_, v_, pos_row,
                                                       max_len))(ks_, vs_)
    x = rms_norm(x, p["ln_dec"], cfg.norm_eps)
    logits = (x[:, -1] @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, EncDecCache(self_kv=self_kv, cross_k=ck, cross_v=cv)


def encdec_decode(p, cfg: ModelConfig, cache: EncDecCache, tokens: Array,
                  pos: Array):
    x = jnp.take(p["embed"], tokens[:, None], axis=0).astype(cfg.compute_dtype)

    def f(x_, lc):
        lp, c_self, ck, cv = lc
        h = rms_norm(x_, lp["ln1"], cfg.norm_eps)
        attn_out, c_new = attn_decode(lp["attn"], cfg, h, pos, c_self)
        x_ = x_ + attn_out
        h = rms_norm(x_, lp["ln2"], cfg.norm_eps)
        x_ = x_ + cross_attn_forward(lp["xattn"], cfg, h, ck, cv)
        h = rms_norm(x_, lp["ln3"], cfg.norm_eps)
        x_ = x_ + mlp_forward(lp["ff"], h)
        return x_, c_new

    x, new_self = jax.lax.scan(
        f, x, (p["dec"], cache.self_kv, cache.cross_k, cache.cross_v))
    x = rms_norm(x, p["ln_dec"], cfg.norm_eps)
    logits = (x[:, 0] @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
    return logits, EncDecCache(self_kv=new_self, cross_k=cache.cross_k,
                               cross_v=cache.cross_v)

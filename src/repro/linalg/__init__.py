from .solvers import (cg_solve, hessian_probabilistic_solver,
                      solution_probabilistic_solver, make_test_matrix)

__all__ = ["cg_solve", "hessian_probabilistic_solver",
           "solution_probabilistic_solver", "make_test_matrix"]

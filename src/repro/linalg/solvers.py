"""Probabilistic linear solvers (paper Sec. 4.2 / 5.1, Fig. 2 reproduction).

Quadratic objective f(x) = 1/2 (x-x*)^T A (x-x*); minimizing == solving
A x = b. Three solvers, all using the optimal quadratic step length
alpha = -d^T g / d^T A d (as the paper's probabilistic methods do):

  * cg_solve                       — the gold-standard baseline
  * solution_probabilistic_solver  — GP-X flipped inference, poly2 kernel
      with c = g_m and prior mean x_m; closed-form Eq. 29 / App. E.2.
      Cost per iteration O(N^2 D + N^3).
  * hessian_probabilistic_solver   — GP-H with fixed c = 0 and prior
      gradient mean g_c = -b (App. F.1); the O(N^2 D + N^3) special case
      of Sec. 4.2 via poly2_quadratic_solve. The paper notes this variant
      "compromises the performance" vs GP-X — reproduced as-is.

All keep the FULL observation history (paper: "retained all the
observations to operate similarly to other probabilistic linear algebra
routines").
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_factors, get_kernel, poly2_quadratic_solve, posterior_hessian
from repro.obs import trace as _obs

Array = jnp.ndarray


def _record(name: str, trace: "SolveTrace") -> "SolveTrace":
    """Publish linalg.<name>.{solves,iters,relres} for a finished solve."""
    if _obs.enabled():
        _obs.REGISTRY.inc(f"linalg.{name}.solves")
        _obs.REGISTRY.observe(f"linalg.{name}.iters", trace.iters)
        _obs.REGISTRY.set_gauge(f"linalg.{name}.relres",
                                float(trace.relres[-1]))
    return trace


class SolveTrace(NamedTuple):
    x: Array
    relres: np.ndarray      # ||A x_t - b|| / ||A x_0 - b|| per iteration
    iters: int


def make_test_matrix(d: int, *, lam_min: float = 0.5, lam_max: float = 100.0,
                     rho: float = 0.6, seed: int = 0) -> Array:
    """App. F.1 spectrum: ~15 eigenvalues in [1, 100], rest near 0.5,
    condition number 200.

    NOTE: the paper's literal formula
    lam_i = lam_min + (lam_max-lam_min)/(N-1) * rho^{N-i} * (N-i)
    peaks at ~2.34 (max of x*rho^x is 0.72/(N-1)-scaled), contradicting its
    own stated lam_max = 100 / kappa = 200. We therefore normalize the
    shape term to hit lam_max exactly — this reproduces every property the
    paper states (~15 large eigenvalues, kappa = 200, CG converging in
    "slightly more than 15 iterations").
    """
    i = np.arange(1, d + 1, dtype=np.float64)
    shape = rho ** (d - i) * (d - i)
    shape[-1] = 0.0
    lam = lam_min + (lam_max - lam_min) * shape / shape.max()
    rng = np.random.RandomState(seed)
    Q, _ = np.linalg.qr(rng.randn(d, d))
    return jnp.asarray(Q @ np.diag(lam) @ Q.T)


def _run(step_dir: Callable, A: Array, b: Array, x0: Array, tol: float,
         max_iters: int, name: str = "solver") -> SolveTrace:
    """Shared loop: direction from `step_dir`, exact quadratic line search."""
    x = jnp.asarray(x0, jnp.float64)
    g = A @ x - b
    g0 = float(jnp.linalg.norm(g))
    hist_x, hist_g = [x], [g]
    rel = [1.0]
    for it in range(max_iters):
        if rel[-1] <= tol:
            break
        if it == 0:
            d = -g                      # Alg. 1 bootstrap: d_0 = -g(x_0)
        else:
            d = step_dir(jnp.stack(hist_x), jnp.stack(hist_g), x, g)
        if float(jnp.vdot(d, g)) > 0:
            d = -d
        dAd = float(d @ (A @ d))
        if not np.isfinite(dAd) or abs(dAd) < 1e-300:
            break
        alpha = float(-(d @ g) / dAd)
        x = x + alpha * d
        g = A @ x - b
        hist_x.append(x)
        hist_g.append(g)
        rel.append(float(jnp.linalg.norm(g)) / g0)
    return _record(name,
                   SolveTrace(x=x, relres=np.array(rel), iters=len(rel) - 1))


def cg_solve(A: Array, b: Array, x0: Array, *, tol: float = 1e-5,
             max_iters: int = 200) -> SolveTrace:
    x = jnp.asarray(x0, jnp.float64)
    r = b - A @ x
    p = r
    g0 = float(jnp.linalg.norm(r))
    rel = [1.0]
    rs = float(r @ r)
    for it in range(max_iters):
        if rel[-1] <= tol:
            break
        Ap = A @ p
        alpha = rs / float(p @ Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = float(r @ r)
        rel.append(float(np.sqrt(rs_new)) / g0)
        p = r + (rs_new / rs) * p
        rs = rs_new
    return _record("cg",
                   SolveTrace(x=x, relres=np.array(rel), iters=len(rel) - 1))


def solution_probabilistic_solver(
    A: Array, b: Array, x0: Array, *, lam: float = 1.0, tol: float = 1e-5,
    max_iters: int = 200, jitter: float = 1e-12,
) -> SolveTrace:
    """GP-X / Eq. 29: poly2 kernel on gradients, c = g_m, prior mean x_m."""

    def direction(X, G, x_m, g_m):
        Xt = X - x_m                      # (N, D) rows
        Gt = G - g_m
        S = (Gt * lam) @ Gt.T             # G~^T Lam G~ in paper layout
        n = S.shape[0]
        Sj = S + jitter * jnp.trace(S) / max(n, 1) * jnp.eye(n, dtype=S.dtype) \
            + 1e-300 * jnp.eye(n, dtype=S.dtype)
        v = -g_m                          # query gradient g_a = 0
        u = (Gt * lam) @ v
        a = jnp.linalg.solve(Sj, u)
        term1 = Xt.T @ a
        bb = Xt @ v - (Gt @ Xt.T) @ a
        term2 = lam * (Gt.T @ jnp.linalg.solve(Sj, bb))
        return term1 + term2              # = x_hat - x_m

    return _run(direction, A, b, x0, tol, max_iters, name="gpx")


def hessian_probabilistic_solver(
    A: Array, b: Array, x0: Array, *, lam: float = 1.0, tol: float = 1e-5,
    max_iters: int = 200, jitter: float = 1e-10,
) -> SolveTrace:
    """GP-H / Sec. 4.2: poly2, fixed c = 0, prior grad mean g_c = -b."""
    spec = get_kernel("poly2")
    d_dim = x0.shape[0]
    c = jnp.zeros((d_dim,), jnp.float64)
    g_c = -jnp.asarray(b, jnp.float64)

    def direction(X, G, x_t, g_t):
        f = build_factors(spec, X, lam=lam, c=c)
        Z = poly2_quadratic_solve(f, G, g_c=g_c, jitter=jitter)
        H = posterior_hessian(spec, x_t, f, Z)
        # H is pure low-rank for dot kernels (diag == 0): regularize with a
        # scale-aware ridge so the Woodbury solve stays sane.
        tau = jnp.maximum(jnp.abs(jnp.trace(H.W @ (H.P.T @ H.P))) / d_dim,
                          1e-12) * 1e-9
        H = H._replace(diag=H.diag + tau)
        return -H.solve(g_t, jitter=jitter)

    return _run(direction, A, b, x0, tol, max_iters, name="gph")
